//! The simulated cluster fabric (DESIGN.md §3).
//!
//! Same rendezvous semantics as [`crate::net::local::LocalFabric`], plus
//! a BSP cost model that produces the *simulated makespan* the scaling
//! figures report:
//!
//! * **Compute** is *measured*, not modeled: each rank thread's CPU time
//!   (`CLOCK_THREAD_CPUTIME_ID`) accrued between fabric calls is folded
//!   into its simulated clock. Thread CPU time is immune to the
//!   timesharing distortion of running p ranks on one core, so a rank
//!   that does n/p rows of real sorting work is charged exactly that
//!   work.
//! * **Communication** is modeled with the α-β model of
//!   [`crate::net::CostModel`], with node topology (ranks_per_node) and
//!   per-rank uplink serialisation: an exchange charges every rank
//!   `max(t_send, t_recv)` on top of the BSP synchronisation point
//!   `max_r(clock_r)`.
//!
//! This is the standard BSP treatment; the paper's own plateau argument
//! (§V-1: "when the parallelism increases, the operation transforms into
//! a communication-bound operation") is exactly the α-term growing with
//! p while per-rank bytes shrink.
//!
//! Fault-domain semantics (recorded [`Fault`], [`Fabric::abort`],
//! collective timeout) mirror [`crate::net::local::LocalFabric`] — see
//! `docs/FAULTS.md`. Timeouts are wall-clock and therefore outside the
//! simulated cost model; they exist so a hung rank still aborts
//! symmetrically under `--fabric sim`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::error::{Result, RylonError};
use crate::net::{CostModel, Fabric, Fault, OutBufs};

/// `CLOCK_THREAD_CPUTIME_ID` read through a direct C binding — the
/// offline registry has no `libc` crate, and the symbol is provided by
/// glibc/musl and by the Darwin libSystem alike. Thread CPU time is
/// immune to the timesharing distortion of running many rank threads on
/// few cores — the property the whole compute-metering model rests on.
#[cfg(any(target_os = "linux", target_os = "macos"))]
fn thread_cpu_seconds() -> f64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    #[cfg(target_os = "linux")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    #[cfg(target_os = "macos")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 16;
    extern "C" {
        fn clock_gettime(clk_id: i32, tp: *mut Timespec) -> i32;
    }
    let mut ts = Timespec {
        tv_sec: 0,
        tv_nsec: 0,
    };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    unsafe {
        clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Last-resort fallback for platforms without a thread-CPU clock: a
/// process-wide monotonic clock. Per-rank segments then absorb
/// scheduler noise and peer compute, so simulated makespans lose their
/// per-rank meaning — correctness tests still pass, the scaling
/// *figures* need a thread-CPU platform.
#[cfg(not(any(target_os = "linux", target_os = "macos")))]
fn thread_cpu_seconds() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

struct State {
    mailbox: Vec<Vec<Option<Vec<u8>>>>,
    posted: usize,
    collected: usize,
    generation: u64,
    /// Simulated seconds per rank.
    clock: Vec<f64>,
    /// Thread-CPU mark per rank (None until the rank's first tick).
    mark: Vec<Option<f64>>,
    /// Total modeled wire bytes (metrics).
    wire_bytes: u64,
    /// Per-rank arrival flags for the current generation.
    arrived: Vec<bool>,
    /// The fault poisoning this fabric, if any. First fault wins.
    fault: Option<Fault>,
}

/// Deterministic BSP cluster simulator.
pub struct SimFabric {
    size: usize,
    cost: CostModel,
    state: Mutex<State>,
    cond: Condvar,
    aborts: AtomicU64,
    /// Collective timeout (wall-clock); `None` parks forever.
    timeout: Option<Duration>,
}

impl SimFabric {
    pub fn new(size: usize, cost: CostModel) -> SimFabric {
        assert!(size > 0, "fabric needs at least one rank");
        SimFabric {
            size,
            cost,
            state: Mutex::new(State {
                mailbox: vec![vec![None; size]; size],
                posted: 0,
                collected: 0,
                generation: 0,
                clock: vec![0.0; size],
                mark: vec![None; size],
                wire_bytes: 0,
                arrived: vec![false; size],
                fault: None,
            }),
            cond: Condvar::new(),
            aborts: AtomicU64::new(0),
            timeout: None,
        }
    }

    /// Abort any collective that does not complete within `timeout`
    /// (wall-clock; attributes the lowest rank that never arrived).
    pub fn with_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.timeout = timeout;
        self
    }

    /// Poison-tolerant lock: metric readers and the fault path must work
    /// even after a rank panicked while holding the state.
    fn lock_tolerant(&self) -> MutexGuard<'_, State> {
        match self.state.lock() {
            Ok(st) => st,
            Err(p) => p.into_inner(),
        }
    }

    /// Lock for the exchange path, converting a poisoned mutex into an
    /// attributed error rather than a panic.
    fn lock(&self, rank: usize) -> Result<MutexGuard<'_, State>> {
        self.state.lock().map_err(|p| {
            let st = p.into_inner();
            match &st.fault {
                Some(f) => f.to_error(),
                None => RylonError::comm(format!(
                    "fabric poisoned: a rank panicked inside exchange #{} \
                     (observed by rank {rank})",
                    st.generation
                )),
            }
        })
    }

    /// One condvar wait, bounded by the deadline (see
    /// `LocalFabric::wait` — identical semantics).
    fn wait<'a>(
        &self,
        st: MutexGuard<'a, State>,
        rank: usize,
        deadline: Option<Instant>,
    ) -> Result<MutexGuard<'a, State>> {
        let poison = |p: std::sync::PoisonError<MutexGuard<'_, State>>| {
            let st = p.into_inner();
            match &st.fault {
                Some(f) => f.to_error(),
                None => RylonError::comm(format!(
                    "fabric poisoned: a rank panicked inside exchange #{} \
                     (observed by rank {rank})",
                    st.generation
                )),
            }
        };
        let Some(dl) = deadline else {
            return self.cond.wait(st).map_err(poison);
        };
        let remaining = dl.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(self.record_timeout(st, rank));
        }
        let (st, _) =
            self.cond.wait_timeout(st, remaining).map_err(poison)?;
        Ok(st)
    }

    /// Record a collective-timeout fault, attributing the lowest rank
    /// that never arrived at the current generation.
    fn record_timeout(
        &self,
        mut st: MutexGuard<'_, State>,
        rank: usize,
    ) -> RylonError {
        if let Some(f) = &st.fault {
            return f.to_error();
        }
        let timeout = self.timeout.unwrap_or_default();
        let missing: Vec<usize> =
            (0..self.size).filter(|&r| !st.arrived[r]).collect();
        let culprit = missing.first().copied().unwrap_or(rank);
        let msg = if missing.is_empty() {
            format!(
                "collective timed out after {timeout:?}: exchange #{} \
                 never closed (observed by rank {rank})",
                st.generation
            )
        } else {
            format!(
                "collective timed out after {timeout:?}: rank(s) \
                 {missing:?} never arrived at exchange #{}",
                st.generation
            )
        };
        let fault = Fault::comm(culprit, "exchange", st.generation, msg);
        st.fault = Some(fault.clone());
        self.aborts.fetch_add(1, Ordering::Relaxed);
        self.cond.notify_all();
        fault.to_error()
    }

    /// Simulated makespan: max over rank clocks (call after the job).
    pub fn makespan(&self) -> f64 {
        let st = self.lock_tolerant();
        st.clock.iter().cloned().fold(0.0, f64::max)
    }

    /// Total bytes charged to the modeled wire.
    pub fn wire_bytes(&self) -> u64 {
        self.lock_tolerant().wire_bytes
    }

    fn fold_compute(&self, st: &mut State, rank: usize) {
        let now = thread_cpu_seconds();
        if let Some(mark) = st.mark[rank] {
            st.clock[rank] += (now - mark).max(0.0);
        }
        st.mark[rank] = Some(now);
    }

    /// Charge the α-β cost of the posted byte matrix (runs once per
    /// generation, by the last poster, while holding the lock).
    fn charge_exchange(&self, st: &mut State) {
        let p = self.size;
        // BSP sync point.
        let start = st.clock.iter().cloned().fold(0.0, f64::max);
        let bytes = |src: usize, dst: usize| -> usize {
            st.mailbox[src][dst].as_ref().map_or(0, |b| b.len())
        };
        for r in 0..p {
            let mut t_send = 0.0;
            let mut t_recv = 0.0;
            for o in 0..p {
                let out_b = bytes(r, o);
                let in_b = bytes(o, r);
                if out_b > 0 || o == r {
                    t_send += self.cost.pt2pt_cost(r, o, out_b);
                }
                if in_b > 0 && o != r {
                    t_recv += self.cost.pt2pt_cost(o, r, in_b);
                }
                st.wire_bytes += out_b as u64;
            }
            st.clock[r] = start + t_send.max(t_recv);
        }
    }
}

impl Fabric for SimFabric {
    fn size(&self) -> usize {
        self.size
    }

    fn bytes_sent(&self) -> u64 {
        self.wire_bytes()
    }

    fn tick_compute(&self, rank: usize) {
        let mut st = self.lock_tolerant();
        self.fold_compute(&mut st, rank);
    }

    fn model_time(&self, rank: usize) -> Option<f64> {
        Some(self.lock_tolerant().clock[rank])
    }

    fn fault(&self) -> Option<Fault> {
        self.lock_tolerant().fault.clone()
    }

    fn abort(&self, fault: Fault) {
        let mut st = self.lock_tolerant();
        if st.fault.is_none() {
            st.fault = Some(fault);
            self.aborts.fetch_add(1, Ordering::Relaxed);
        }
        self.cond.notify_all();
    }

    fn clear_fault(&self) {
        let mut st = self.lock_tolerant();
        st.fault = None;
        st.posted = 0;
        st.collected = 0;
        st.generation += 1;
        st.arrived.fill(false);
        for row in &mut st.mailbox {
            for slot in row {
                *slot = None;
            }
        }
        self.cond.notify_all();
    }

    fn aborts(&self) -> u64 {
        self.aborts.load(Ordering::Relaxed)
    }

    fn exchange(&self, rank: usize, outgoing: OutBufs) -> Result<OutBufs> {
        if outgoing.len() != self.size {
            return Err(RylonError::comm(format!(
                "exchange from rank {rank}: {} buffers for {} ranks",
                outgoing.len(),
                self.size
            )));
        }
        let deadline = self.timeout.map(|t| Instant::now() + t);
        let mut st = self.lock(rank)?;
        if let Some(f) = &st.fault {
            return Err(f.to_error());
        }
        // Fold this rank's compute segment before the superstep.
        self.fold_compute(&mut st, rank);

        let my_gen = st.generation;
        for (dst, buf) in outgoing.into_iter().enumerate() {
            debug_assert!(st.mailbox[rank][dst].is_none());
            st.mailbox[rank][dst] = Some(buf);
        }
        st.posted += 1;
        st.arrived[rank] = true;
        if st.posted == self.size {
            // Last poster charges the comm model for everyone.
            self.charge_exchange(&mut st);
            self.cond.notify_all();
        }
        while st.generation == my_gen && st.posted < self.size {
            st = self.wait(st, rank, deadline)?;
            if let Some(f) = &st.fault {
                return Err(f.to_error());
            }
        }

        let mut incoming: OutBufs = Vec::with_capacity(self.size);
        for src in 0..self.size {
            match st.mailbox[src][rank].take() {
                Some(buf) => incoming.push(buf),
                None => {
                    let fault = Fault::comm(
                        src,
                        "exchange",
                        st.generation,
                        format!(
                            "mailbox slot empty: rank {src} never \
                             delivered to rank {rank} in exchange #{}",
                            st.generation
                        ),
                    );
                    if st.fault.is_none() {
                        st.fault = Some(fault.clone());
                        self.aborts.fetch_add(1, Ordering::Relaxed);
                    }
                    self.cond.notify_all();
                    return Err(fault.to_error());
                }
            }
        }
        st.collected += 1;
        if st.collected == self.size {
            st.posted = 0;
            st.collected = 0;
            st.generation += 1;
            st.arrived.fill(false);
            self.cond.notify_all();
        } else {
            let gen = st.generation;
            while st.generation == gen {
                st = self.wait(st, rank, deadline)?;
                if let Some(f) = &st.fault {
                    return Err(f.to_error());
                }
            }
        }
        // Restart the compute mark *after* the rendezvous so time spent
        // blocked on slower ranks is never charged as compute.
        let now = thread_cpu_seconds();
        st.mark[rank] = Some(now);
        Ok(incoming)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn run_ranks<F, T>(fab: Arc<SimFabric>, f: F) -> Vec<T>
    where
        F: Fn(usize, Arc<SimFabric>) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let size = fab.size();
        let f = Arc::new(f);
        let handles: Vec<_> = (0..size)
            .map(|r| {
                let fab = Arc::clone(&fab);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(r, fab))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn routes_like_local_fabric() {
        let fab = Arc::new(SimFabric::new(3, CostModel::default()));
        let results = run_ranks(Arc::clone(&fab), move |rank, fab| {
            let out: OutBufs =
                (0..3).map(|d| vec![rank as u8, d as u8]).collect();
            fab.exchange(rank, out).unwrap()
        });
        for (dst, incoming) in results.iter().enumerate() {
            for (src, buf) in incoming.iter().enumerate() {
                assert_eq!(buf, &vec![src as u8, dst as u8]);
            }
        }
    }

    #[test]
    fn comm_cost_scales_with_bytes() {
        let small = {
            let fab = Arc::new(SimFabric::new(2, CostModel::default()));
            run_ranks(Arc::clone(&fab), |rank, fab| {
                fab.exchange(rank, vec![vec![0u8; 10], vec![0u8; 10]])
                    .unwrap();
            });
            fab.makespan()
        };
        let big = {
            let fab = Arc::new(SimFabric::new(2, CostModel::default()));
            run_ranks(Arc::clone(&fab), |rank, fab| {
                fab.exchange(
                    rank,
                    vec![vec![0u8; 10_000_000], vec![0u8; 10_000_000]],
                )
                .unwrap();
            });
            fab.makespan()
        };
        assert!(big > small * 10.0, "big={big} small={small}");
    }

    #[test]
    fn latency_term_grows_with_ranks() {
        // Tiny messages: cost ≈ α·(p−1), so 8 ranks ≫ 2 ranks.
        let t = |p: usize| {
            let fab = Arc::new(SimFabric::new(p, CostModel::default()));
            run_ranks(Arc::clone(&fab), move |rank, fab| {
                fab.exchange(rank, vec![vec![1u8]; p]).unwrap();
            });
            fab.makespan()
        };
        assert!(t(8) > t(2) * 2.0);
    }

    #[test]
    fn compute_is_metered() {
        let fab = Arc::new(SimFabric::new(2, CostModel::default()));
        run_ranks(Arc::clone(&fab), |rank, fab| {
            fab.tick_compute(rank);
            // Burn real CPU.
            let mut acc = 0u64;
            for i in 0..20_000_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
            fab.exchange(rank, vec![vec![], vec![]]).unwrap();
        });
        assert!(
            fab.makespan() > 0.001,
            "expected metered compute, got {}",
            fab.makespan()
        );
    }

    #[test]
    fn wire_bytes_accumulate() {
        let fab = Arc::new(SimFabric::new(2, CostModel::default()));
        run_ranks(Arc::clone(&fab), |rank, fab| {
            fab.exchange(rank, vec![vec![0u8; 100], vec![0u8; 100]])
                .unwrap();
        });
        assert_eq!(fab.wire_bytes(), 400);
    }
}
