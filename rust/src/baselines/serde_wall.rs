//! The language-boundary serialization wall, executed for real.
//!
//! PySpark's documented bottleneck (paper §II-A) is that every
//! Python↔JVM crossing pickles rows value-by-value: a tagged,
//! self-describing, row-major format with per-value dispatch — nothing
//! like the columnar memcpy of `net::wire`. This module implements such
//! a codec; the spark/dask/modin simulators call [`cross_wall`] at every
//! stage boundary so the cost is *measured work*, not a constant.

use crate::error::{Result, RylonError};
use crate::table::Table;
use crate::types::{DataType, Field, Schema, Value};

/// Encode a table row-major with per-value tags (pickle-style).
pub fn encode_rows(table: &Table) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(table.num_columns() as u32).to_le_bytes());
    for f in table.schema().fields() {
        let name = f.name.as_bytes();
        out.push(match f.dtype {
            DataType::Int64 => 0,
            DataType::Float64 => 1,
            DataType::Utf8 => 2,
            DataType::Bool => 3,
        });
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
    }
    out.extend_from_slice(&(table.num_rows() as u64).to_le_bytes());
    // Row-major, boxed access per cell — the whole point.
    for r in 0..table.num_rows() {
        for c in 0..table.num_columns() {
            match table.column(c).value(r) {
                Value::Null => out.push(0),
                Value::Int64(v) => {
                    out.push(1);
                    out.extend_from_slice(&v.to_le_bytes());
                }
                Value::Float64(v) => {
                    out.push(2);
                    out.extend_from_slice(&v.to_le_bytes());
                }
                Value::Utf8(s) => {
                    out.push(3);
                    out.extend_from_slice(
                        &(s.len() as u32).to_le_bytes(),
                    );
                    out.extend_from_slice(s.as_bytes());
                }
                Value::Bool(b) => {
                    out.push(4);
                    out.push(b as u8);
                }
            }
        }
    }
    out
}

/// Decode a row-major buffer back into a columnar table.
pub fn decode_rows(buf: &[u8]) -> Result<Table> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > buf.len() {
            return Err(RylonError::parse("row buffer truncated"));
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let ncols =
        u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut fields = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        let dtype = match take(&mut pos, 1)?[0] {
            0 => DataType::Int64,
            1 => DataType::Float64,
            2 => DataType::Utf8,
            3 => DataType::Bool,
            t => {
                return Err(RylonError::parse(format!("bad dtype tag {t}")))
            }
        };
        let nlen = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap())
            as usize;
        let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
            .map_err(|_| RylonError::parse("bad column name"))?;
        fields.push(Field::new(name, dtype));
    }
    let nrows =
        u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let schema = Schema::new(fields);
    let mut builders: Vec<crate::column::ColumnBuilder> = schema
        .fields()
        .iter()
        .map(|f| crate::column::ColumnBuilder::new(f.dtype, nrows))
        .collect();
    for _ in 0..nrows {
        for b in builders.iter_mut() {
            let tag = take(&mut pos, 1)?[0];
            let v = match tag {
                0 => Value::Null,
                1 => Value::Int64(i64::from_le_bytes(
                    take(&mut pos, 8)?.try_into().unwrap(),
                )),
                2 => Value::Float64(f64::from_le_bytes(
                    take(&mut pos, 8)?.try_into().unwrap(),
                )),
                3 => {
                    let n = u32::from_le_bytes(
                        take(&mut pos, 4)?.try_into().unwrap(),
                    ) as usize;
                    Value::Utf8(
                        String::from_utf8(take(&mut pos, n)?.to_vec())
                            .map_err(|_| {
                                RylonError::parse("bad utf8 cell")
                            })?,
                    )
                }
                4 => Value::Bool(take(&mut pos, 1)?[0] != 0),
                t => {
                    return Err(RylonError::parse(format!(
                        "bad value tag {t}"
                    )))
                }
            };
            b.push_value(&v)?;
        }
    }
    Table::try_new(
        schema,
        builders.into_iter().map(|b| b.finish()).collect(),
    )
}

/// One full boundary crossing: encode then decode (e.g. JVM → wire
/// format → Python objects). Returns the re-materialised table.
pub fn cross_wall(table: &Table) -> Result<Table> {
    decode_rows(&encode_rows(table))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_opt_i64(vec![Some(1), None])),
            ("v", Column::from_f64(vec![0.5, -1.5])),
            ("s", Column::from_str(&["a", "bc"])),
            ("b", Column::from_bool(vec![true, false])),
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let back = cross_wall(&t()).unwrap();
        assert_eq!(back, t());
    }

    #[test]
    fn wall_is_bulkier_than_wire() {
        // The pickle-style format must cost more bytes than the columnar
        // wire format for numeric tables (per-value tags).
        let big = Table::from_columns(vec![(
            "x",
            Column::from_i64((0..1000).collect()),
        )])
        .unwrap();
        let wall = encode_rows(&big).len();
        let wire = crate::net::wire::serialize_table(&big).len();
        assert!(wall > wire, "wall={wall} wire={wire}");
    }

    #[test]
    fn truncation_rejected() {
        let buf = encode_rows(&t());
        assert!(decode_rows(&buf[..buf.len() - 3]).is_err());
    }
}
