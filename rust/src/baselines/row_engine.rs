//! Boxed-row execution engine: `Vec<Vec<Value>>` rows, every cell an
//! enum, every comparison dynamically dispatched — the executed stand-in
//! for Python-level dataframe kernels (the paper's critique of
//! pure-Python engines, §II-B). Same asymptotics as the columnar
//! operators (sort-merge join, hash groupby); the constant factor *is*
//! the measurement.

use std::cmp::Ordering;

use crate::error::{Result, RylonError};
use crate::table::Table;
use crate::types::{Schema, Value};

/// A table materialised as boxed rows.
#[derive(Debug, Clone)]
pub struct RowTable {
    pub schema: Schema,
    pub rows: Vec<Vec<Value>>,
}

impl RowTable {
    /// Box a columnar table (this conversion cost is part of what the
    /// row engine measures — Python engines pay it on ingest).
    pub fn from_table(t: &Table) -> RowTable {
        RowTable {
            schema: t.schema().clone(),
            rows: (0..t.num_rows()).map(|i| t.row(i)).collect(),
        }
    }

    /// Un-box back to columnar.
    pub fn to_table(&self) -> Result<Table> {
        let mut builders: Vec<crate::column::ColumnBuilder> = self
            .schema
            .fields()
            .iter()
            .map(|f| crate::column::ColumnBuilder::new(f.dtype, self.rows.len()))
            .collect();
        for row in &self.rows {
            if row.len() != builders.len() {
                return Err(RylonError::schema("ragged boxed row"));
            }
            for (b, v) in builders.iter_mut().zip(row) {
                b.push_value(v)?;
            }
        }
        Table::try_new(
            self.schema.clone(),
            builders.into_iter().map(|b| b.finish()).collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Row-wise filter with a boxed predicate.
    pub fn filter<F: FnMut(&[Value]) -> bool>(&self, mut pred: F) -> RowTable {
        RowTable {
            schema: self.schema.clone(),
            rows: self
                .rows
                .iter()
                .filter(|r| pred(r))
                .cloned()
                .collect(),
        }
    }

    /// Sort-merge inner join on one key column per side — dynamically
    /// dispatched `Value::total_cmp` per comparison, exactly the cost
    /// profile of an interpreted engine.
    pub fn inner_join(
        &self,
        other: &RowTable,
        left_on: &str,
        right_on: &str,
    ) -> Result<RowTable> {
        let lk = self.schema.index_of(left_on)?;
        let rk = other.schema.index_of(right_on)?;
        let mut lrows: Vec<&Vec<Value>> = self.rows.iter().collect();
        let mut rrows: Vec<&Vec<Value>> = other.rows.iter().collect();
        lrows.sort_by(|a, b| a[lk].total_cmp(&b[lk]));
        rrows.sort_by(|a, b| a[rk].total_cmp(&b[rk]));

        let mut out_rows = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < lrows.len() && j < rrows.len() {
            // Null keys never match.
            if lrows[i][lk].is_null() {
                i += 1;
                continue;
            }
            if rrows[j][rk].is_null() {
                j += 1;
                continue;
            }
            match lrows[i][lk].total_cmp(&rrows[j][rk]) {
                Ordering::Less => i += 1,
                Ordering::Greater => j += 1,
                Ordering::Equal => {
                    let mut i_end = i + 1;
                    while i_end < lrows.len()
                        && lrows[i_end][lk].total_cmp(&lrows[i][lk])
                            == Ordering::Equal
                    {
                        i_end += 1;
                    }
                    let mut j_end = j + 1;
                    while j_end < rrows.len()
                        && rrows[j_end][rk].total_cmp(&rrows[j][rk])
                            == Ordering::Equal
                    {
                        j_end += 1;
                    }
                    for li in i..i_end {
                        for rj in j..j_end {
                            let mut row = lrows[li].clone();
                            row.extend(rrows[rj].iter().cloned());
                            out_rows.push(row);
                        }
                    }
                    i = i_end;
                    j = j_end;
                }
            }
        }
        Ok(RowTable {
            schema: self.schema.join(&other.schema, "_right"),
            rows: out_rows,
        })
    }

    /// Hash groupby-sum over one key and one value column (enough for
    /// the baseline benches).
    pub fn groupby_sum(&self, key: &str, val: &str) -> Result<RowTable> {
        let k = self.schema.index_of(key)?;
        let v = self.schema.index_of(val)?;
        let mut groups: std::collections::HashMap<String, f64> =
            std::collections::HashMap::new();
        for row in &self.rows {
            // Dynamic render-keyed grouping — deliberately the kind of
            // thing interpreted engines do.
            let gk = row[k].render();
            *groups.entry(gk).or_insert(0.0) +=
                row[v].as_f64().unwrap_or(0.0);
        }
        let schema = Schema::parse("key:str,sum:f64").unwrap();
        let rows = groups
            .into_iter()
            .map(|(k, s)| vec![Value::Utf8(k), Value::Float64(s)])
            .collect();
        Ok(RowTable { schema, rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::join::{join, JoinOptions};

    fn t(keys: Vec<i64>) -> Table {
        let vals: Vec<f64> = keys.iter().map(|&k| k as f64).collect();
        Table::from_columns(vec![
            ("k", Column::from_i64(keys)),
            ("v", Column::from_f64(vals)),
        ])
        .unwrap()
    }

    #[test]
    fn box_unbox_roundtrip() {
        let table = t(vec![3, 1, 2]);
        let rt = RowTable::from_table(&table);
        assert_eq!(rt.len(), 3);
        assert_eq!(rt.to_table().unwrap(), table);
    }

    #[test]
    fn row_join_matches_columnar_join() {
        let l = t(vec![1, 2, 2, 5]);
        let r = t(vec![2, 2, 5, 9]);
        let row_out = RowTable::from_table(&l)
            .inner_join(&RowTable::from_table(&r), "k", "k")
            .unwrap();
        let col_out =
            join(&l, &r, &JoinOptions::inner("k", "k")).unwrap();
        assert_eq!(row_out.len(), col_out.num_rows()); // 2×2 + 1 = 5
        assert_eq!(row_out.len(), 5);
    }

    #[test]
    fn null_keys_skipped() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_opt_i64(vec![None, Some(1)]),
        )])
        .unwrap();
        let out = RowTable::from_table(&l)
            .inner_join(&RowTable::from_table(&l), "k", "k")
            .unwrap();
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn filter_and_groupby() {
        let rt = RowTable::from_table(&t(vec![1, 1, 2]));
        let f = rt.filter(|row| row[0].as_i64() == Some(1));
        assert_eq!(f.len(), 2);
        let g = rt.groupby_sum("k", "v").unwrap();
        assert_eq!(g.len(), 2);
        let one = g
            .rows
            .iter()
            .find(|r| r[0].as_str() == Some("1"))
            .unwrap();
        assert_eq!(one[1].as_f64(), Some(2.0));
    }
}
