//! Baseline mechanism simulators (DESIGN.md §4) — the comparators of the
//! paper's Fig 10/11: PySpark, Dask-distributed and Modin/Ray, rebuilt
//! as *executed mechanisms* on the same table substrate so the measured
//! differences come from the mechanisms the paper blames, not fudge
//! factors:
//!
//! * [`row_engine`] — boxed `Vec<Value>` rows with enum-dispatched
//!   dynamic typing: the stand-in for Python-level compute kernels
//!   (same asymptotics as Pandas-on-objects, interpreted-style constant
//!   factor).
//! * [`serde_wall`] — a pickle-like tagged row codec: the
//!   JVM↔Python / worker↔object-store serialization boundary, executed
//!   for real on every crossing.
//! * [`engines`] — the four [`engines::JoinEngine`]s (rylon, spark_sim,
//!   dask_sim, modin_sim) the figure benches sweep.

pub mod row_engine;
pub mod serde_wall;
pub mod engines;

pub use engines::{
    DaskSimEngine, JoinEngine, ModinSimEngine, RylonEngine, SparkSimEngine,
};
