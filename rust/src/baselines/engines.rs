//! The four distributed join engines benchmarked by Figs 10/11. All run
//! on the same fabric and the same data; they differ only in the
//! execution mechanisms the paper attributes their performance to
//! (DESIGN.md §4). Everything is executed work — metered by the sim
//! fabric's thread-CPU clock — not tuned constants.

use crate::baselines::row_engine::RowTable;
use crate::baselines::serde_wall::cross_wall;
use crate::dist::{dist_join, shuffle, RankCtx};
use crate::error::Result;
use crate::net::collectives::{bcast, gather};
use crate::ops::join::{join, JoinOptions};
use crate::table::Table;

/// A distributed inner-join implementation under benchmark.
pub trait JoinEngine: Send + Sync {
    fn name(&self) -> &'static str;

    /// SPMD distributed join: called per rank with local partitions.
    fn dist_join(
        &self,
        ctx: &mut RankCtx,
        left: &Table,
        right: &Table,
        opts: &JoinOptions,
    ) -> Result<Table>;
}

/// Ours — the Cylon role: columnar kernels, columnar wire format,
/// no driver in the data path.
pub struct RylonEngine;

impl JoinEngine for RylonEngine {
    fn name(&self) -> &'static str {
        "rylon"
    }

    fn dist_join(
        &self,
        ctx: &mut RankCtx,
        left: &Table,
        right: &Table,
        opts: &JoinOptions,
    ) -> Result<Table> {
        dist_join(ctx, left, right, opts)
    }
}

/// One driver (rank 0) round trip: workers report readiness, driver
/// broadcasts stage assignments — the per-stage scheduling latency of a
/// driver-coordinated dataflow engine. Payloads are small; the α-term
/// (and the rendezvous) is the cost.
fn driver_round_trip(ctx: &mut RankCtx, stage: &str) -> Result<()> {
    let fab = ctx.fabric();
    let _ = gather(
        fab,
        ctx.rank,
        0,
        format!("ready:{stage}:{}", ctx.rank).into_bytes(),
    )?;
    let _ = bcast(fab, ctx.rank, 0, format!("run:{stage}").into_bytes())?;
    Ok(())
}

/// "PySpark": JVM dataflow — fast columnar compute, but every stage
/// boundary serialises rows through the language wall, and the driver
/// schedules every stage (paper §II-A: "it consumes a significant amount
/// of additional CPU cycles for data serialization/deserialization").
pub struct SparkSimEngine;

impl JoinEngine for SparkSimEngine {
    fn name(&self) -> &'static str {
        "spark_sim"
    }

    fn dist_join(
        &self,
        ctx: &mut RankCtx,
        left: &Table,
        right: &Table,
        opts: &JoinOptions,
    ) -> Result<Table> {
        // Stage 1: shuffle-write both relations. Rows cross the wall on
        // the way out (JVM row format) and on the way in.
        driver_round_trip(ctx, "shuffle-left")?;
        let l = cross_wall(left)?;
        let l = shuffle(ctx, &l, &opts.left_on)?;
        let l = cross_wall(&l)?;

        driver_round_trip(ctx, "shuffle-right")?;
        let r = cross_wall(right)?;
        let r = shuffle(ctx, &r, &opts.right_on)?;
        let r = cross_wall(&r)?;

        // Stage 2: local join — columnar (JVM compute is fast; Spark's
        // cost is the boundary + coordination).
        driver_round_trip(ctx, "join")?;
        join(&l, &r, opts)
    }
}

/// "Dask-distributed": centralized scheduler dispatching per-partition
/// tasks, pickled partitions on the wire, and Python-level (boxed-row)
/// compute kernels.
pub struct DaskSimEngine;

impl JoinEngine for DaskSimEngine {
    fn name(&self) -> &'static str {
        "dask_sim"
    }

    fn dist_join(
        &self,
        ctx: &mut RankCtx,
        left: &Table,
        right: &Table,
        opts: &JoinOptions,
    ) -> Result<Table> {
        // Dask's graph has one task per partition per stage, each
        // acknowledged by the central scheduler (two round trips per
        // stage: task dispatch + completion report).
        driver_round_trip(ctx, "graph-build")?;
        driver_round_trip(ctx, "dispatch-left")?;
        let l = cross_wall(left)?; // pickle partition
        let l = shuffle(ctx, &l, &opts.left_on)?;
        driver_round_trip(ctx, "complete-left")?;
        driver_round_trip(ctx, "dispatch-right")?;
        let r = cross_wall(right)?;
        let r = shuffle(ctx, &r, &opts.right_on)?;
        driver_round_trip(ctx, "complete-right")?;

        // Python-level compute: boxed rows, dynamic dispatch.
        driver_round_trip(ctx, "dispatch-join")?;
        let lrow = RowTable::from_table(&l);
        let rrow = RowTable::from_table(&r);
        let out = lrow.inner_join(
            &rrow,
            &opts.left_on[0],
            &opts.right_on[0],
        )?;
        driver_round_trip(ctx, "complete-join")?;
        out.to_table()
    }
}

/// "Modin/Ray 0.6.3": boxed-row kernels, an object-store round trip
/// around every operator, and a *serial driver section* — the driver
/// materialises the full result through the store (the behaviour behind
/// the paper's "performs poorly for strong scaling" finding).
pub struct ModinSimEngine;

impl JoinEngine for ModinSimEngine {
    fn name(&self) -> &'static str {
        "modin_sim"
    }

    fn dist_join(
        &self,
        ctx: &mut RankCtx,
        left: &Table,
        right: &Table,
        opts: &JoinOptions,
    ) -> Result<Table> {
        // Object-store put/get around each input.
        driver_round_trip(ctx, "put-left")?;
        let l = cross_wall(&cross_wall(left)?)?; // put + get
        let l = shuffle(ctx, &l, &opts.left_on)?;
        driver_round_trip(ctx, "put-right")?;
        let r = cross_wall(&cross_wall(right)?)?;
        let r = shuffle(ctx, &r, &opts.right_on)?;

        // Python compute on boxed rows.
        let out = RowTable::from_table(&l)
            .inner_join(
                &RowTable::from_table(&r),
                &opts.left_on[0],
                &opts.right_on[0],
            )?
            .to_table()?;

        // Serial driver section: the whole result funnels through the
        // driver's store (gather → driver decodes/encodes → broadcast
        // row counts). This is the Amdahl term that flattens scaling.
        let fab = ctx.fabric();
        let payload =
            crate::baselines::serde_wall::encode_rows(&out);
        let gathered = gather(fab, ctx.rank, 0, payload)?;
        if let Some(bufs) = gathered {
            // Driver re-materialises every partition (serial work at
            // rank 0, metered as its compute).
            let mut total = 0usize;
            for b in &bufs {
                let t = crate::baselines::serde_wall::decode_rows(b)?;
                total += t.num_rows();
            }
            let _ = bcast(fab, ctx.rank, 0, total.to_le_bytes().to_vec())?;
        } else {
            let _ = bcast(fab, ctx.rank, 0, Vec::new())?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::dist::{Cluster, DistConfig};
    use crate::types::Value;

    fn engines() -> Vec<Box<dyn JoinEngine>> {
        vec![
            Box::new(RylonEngine),
            Box::new(SparkSimEngine),
            Box::new(DaskSimEngine),
            Box::new(ModinSimEngine),
        ]
    }

    /// All four engines must produce the same join result — the
    /// baselines are slower, never wrong.
    #[test]
    fn all_engines_agree() {
        let world = 3;
        let opts = JoinOptions::inner("id", "id");
        let mut reference: Option<Vec<Vec<Value>>> = None;
        for engine in engines() {
            let cluster =
                Cluster::new(DistConfig::threads(world)).unwrap();
            let outs = cluster
                .run(|ctx| {
                    let rank = ctx.rank as i64;
                    let l = Table::from_columns(vec![
                        (
                            "id",
                            Column::from_i64(
                                (0..20).map(|i| (i + rank * 3) % 11).collect(),
                            ),
                        ),
                        (
                            "v",
                            Column::from_f64(
                                (0..20).map(|i| i as f64).collect(),
                            ),
                        ),
                    ])
                    .unwrap();
                    let r = Table::from_columns(vec![
                        (
                            "id",
                            Column::from_i64(
                                (0..15).map(|i| (i * 2 + rank) % 13).collect(),
                            ),
                        ),
                        (
                            "w",
                            Column::from_f64(
                                (0..15).map(|i| -(i as f64)).collect(),
                            ),
                        ),
                    ])
                    .unwrap();
                    engine.dist_join(ctx, &l, &r, &opts)
                })
                .unwrap();
            let all = Table::concat_all(outs[0].schema(), &outs).unwrap();
            let mut rows: Vec<Vec<Value>> =
                (0..all.num_rows()).map(|i| all.row(i)).collect();
            rows.sort_by(|a, b| {
                for (x, y) in a.iter().zip(b) {
                    let o = x.total_cmp(y);
                    if o != std::cmp::Ordering::Equal {
                        return o;
                    }
                }
                std::cmp::Ordering::Equal
            });
            match &reference {
                None => reference = Some(rows),
                Some(r) => {
                    assert_eq!(&rows, r, "engine {}", engine.name())
                }
            }
        }
    }

    /// On the sim fabric, the baseline mechanisms must cost more than
    /// rylon on the same workload — the Fig 10 ordering.
    #[test]
    fn baselines_cost_more_than_rylon() {
        use crate::net::CostModel;
        let opts = JoinOptions::inner("id", "id");
        let mut times = std::collections::HashMap::new();
        for engine in engines() {
            let cluster =
                Cluster::new(DistConfig::sim(2, CostModel::default()))
                    .unwrap();
            cluster
                .run(|ctx| {
                    let l = crate::io::datagen::gen_partition(
                        &crate::io::datagen::DataGenSpec::paper_scaling(
                            8000, 1,
                        ),
                        ctx.rank,
                        ctx.size,
                    )?;
                    let r = crate::io::datagen::gen_partition(
                        &crate::io::datagen::DataGenSpec::paper_scaling(
                            8000, 2,
                        ),
                        ctx.rank,
                        ctx.size,
                    )?;
                    engine.dist_join(ctx, &l, &r, &opts)
                })
                .unwrap();
            times.insert(engine.name(), cluster.makespan().unwrap());
        }
        let rylon = times["rylon"];
        assert!(times["spark_sim"] > rylon, "{times:?}");
        assert!(times["dask_sim"] > rylon, "{times:?}");
        assert!(times["modin_sim"] > rylon, "{times:?}");
    }
}
