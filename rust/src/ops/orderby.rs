//! OrderBy — sort a table by one or more key columns (DataTable API
//! surface; also the local phase of `dist::dist_sort`'s sample sort).

use crate::compute::filter::take_parallel;
use crate::compute::sort::{argsort_by_columns, argsort_i64};
use crate::column::Column;
use crate::error::Result;
use crate::exec;
use crate::table::Table;

/// Sort direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortOrder {
    Ascending,
    Descending,
}

/// One sort key.
#[derive(Debug, Clone)]
pub struct SortKey {
    pub column: String,
    pub order: SortOrder,
}

impl SortKey {
    pub fn asc(column: &str) -> SortKey {
        SortKey {
            column: column.to_string(),
            order: SortOrder::Ascending,
        }
    }

    pub fn desc(column: &str) -> SortKey {
        SortKey {
            column: column.to_string(),
            order: SortOrder::Descending,
        }
    }
}

/// Sort the table by the given keys (stable; nulls first ascending,
/// last descending — the inverse holds by symmetry of reversal).
pub fn orderby(table: &Table, keys: &[SortKey]) -> Result<Table> {
    if keys.is_empty() {
        return Ok(table.clone());
    }
    let cols: Result<Vec<&Column>> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect();
    let cols = cols?;
    let desc: Vec<bool> = keys
        .iter()
        .map(|k| k.order == SortOrder::Descending)
        .collect();
    // Radix fast path: single ascending i64 key.
    let perm = if cols.len() == 1 && !desc[0] {
        if let Column::Int64(c) = cols[0] {
            argsort_i64(c.values(), c.validity())
        } else {
            argsort_by_columns(&cols, &desc, table.num_rows())
        }
    } else {
        argsort_by_columns(&cols, &desc, table.num_rows())
    };
    // Morsel-parallel (and steal-eligible) materialisation — equals
    // `table.take(&perm)` bit for bit.
    Ok(take_parallel(
        table,
        &perm,
        exec::parallelism_for(perm.len()),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_opt_i64(vec![Some(3), None, Some(1), Some(3)])),
            ("v", Column::from_str(&["x", "y", "z", "w"])),
        ])
        .unwrap()
    }

    #[test]
    fn single_key_asc_radix_path() {
        let s = orderby(&t(), &[SortKey::asc("k")]).unwrap();
        // Nulls first, then 1, 3, 3 (stable: "x" before "w").
        assert!(s.column(0).value(0).is_null());
        assert_eq!(s.column(0).i64_values()[1..], [1, 3, 3]);
        assert_eq!(s.column(1).value(2).as_str(), Some("x"));
        assert_eq!(s.column(1).value(3).as_str(), Some("w"));
    }

    #[test]
    fn descending() {
        let s = orderby(&t(), &[SortKey::desc("k")]).unwrap();
        assert_eq!(s.column(0).i64_values()[..3], [3, 3, 1]);
        assert!(s.column(0).value(3).is_null());
    }

    #[test]
    fn multi_key_tiebreak() {
        let s =
            orderby(&t(), &[SortKey::asc("k"), SortKey::desc("v")]).unwrap();
        // k=3 run ordered by v desc: "x" then "w".
        assert_eq!(s.column(1).value(2).as_str(), Some("x"));
        assert_eq!(s.column(1).value(3).as_str(), Some("w"));
    }

    #[test]
    fn empty_keys_identity() {
        let s = orderby(&t(), &[]).unwrap();
        assert_eq!(s, t());
    }

    #[test]
    fn missing_column() {
        assert!(orderby(&t(), &[SortKey::asc("ghost")]).is_err());
    }
}
