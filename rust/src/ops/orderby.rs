//! OrderBy — sort a table by one or more key columns (DataTable API
//! surface; also the local phase of `dist::dist_sort`'s sample sort).
//!
//! When the per-rank memory governor denies the in-memory sort's
//! working set, [`orderby`] degrades to an **external merge sort**:
//! budget-sized contiguous runs are stably sorted one at a time,
//! spilled as RYF row groups under a per-episode spill directory, read
//! back, and stably merged (ties take the earlier run) through the
//! same merge-level machinery the parallel in-memory sort uses — the
//! output is bit-identical to the unbounded path (`docs/MEMORY.md`).

use std::cmp::Ordering;

use crate::compute::filter::take_parallel;
use crate::compute::sort::{
    argsort_by_columns, argsort_i64, merge_runs_stable_by,
};
use crate::column::Column;
use crate::error::Result;
use crate::exec::{self, MemoryBudget, SpillDir};
use crate::io::ryf::{read_ryf_footer, read_ryf_group, RyfWriter};
use crate::table::Table;

/// Sort direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortOrder {
    Ascending,
    Descending,
}

/// One sort key.
#[derive(Debug, Clone)]
pub struct SortKey {
    pub column: String,
    pub order: SortOrder,
}

impl SortKey {
    pub fn asc(column: &str) -> SortKey {
        SortKey {
            column: column.to_string(),
            order: SortOrder::Ascending,
        }
    }

    pub fn desc(column: &str) -> SortKey {
        SortKey {
            column: column.to_string(),
            order: SortOrder::Descending,
        }
    }
}

/// Sort the table by the given keys (stable; nulls first ascending,
/// last descending — the inverse holds by symmetry of reversal).
pub fn orderby(table: &Table, keys: &[SortKey]) -> Result<Table> {
    if keys.is_empty() {
        return Ok(table.clone());
    }
    let cols: Result<Vec<&Column>> = keys
        .iter()
        .map(|k| table.column_by_name(&k.column))
        .collect();
    let cols = cols?;
    let desc: Vec<bool> = keys
        .iter()
        .map(|k| k.order == SortOrder::Descending)
        .collect();
    // Declared working set: the sorted copy plus the permutation. If
    // the governor denies it, sort out of core instead.
    let budget = MemoryBudget::current();
    let need = table.byte_size() + 8 * table.num_rows();
    let held = budget.try_reserve(need);
    if held.is_none() && table.num_rows() > 0 {
        return external_sort(table, keys, &desc, &budget);
    }
    // Radix fast path: single ascending i64 key.
    let perm = if cols.len() == 1 && !desc[0] {
        if let Column::Int64(c) = cols[0] {
            argsort_i64(c.values(), c.validity())
        } else {
            argsort_by_columns(&cols, &desc, table.num_rows())
        }
    } else {
        argsort_by_columns(&cols, &desc, table.num_rows())
    };
    // Morsel-parallel (and steal-eligible) materialisation — equals
    // `table.take(&perm)` bit for bit.
    Ok(take_parallel(
        table,
        &perm,
        exec::parallelism_for(perm.len()),
    ))
}

/// Smallest external-sort run, in rows: below this, run overhead (one
/// RYF group per run) dwarfs any memory saving, so the budget-derived
/// run size is floored here even when the budget is smaller.
const MIN_RUN_ROWS: usize = 256;

/// External merge sort (module docs): stably sorted budget-sized runs
/// spilled as RYF groups, then a stable ties-take-left merge of the
/// index runs over the read-back concatenation. Both the serial
/// comparator sort and the radix fast path produce *the* stable
/// permutation (nulls first, ties in input order), so one comparator
/// merge reproduces either.
fn external_sort(
    table: &Table,
    keys: &[SortKey],
    desc: &[bool],
    budget: &MemoryBudget,
) -> Result<Table> {
    let n = table.num_rows();
    // Run size: each run's sorted copy + permutation should fit about
    // half the budget, leaving headroom for the merge's chunk buffers.
    let per_row = (table.byte_size() / n).max(1) + 8;
    let run_rows = if budget.limit() == 0 {
        n
    } else {
        (budget.limit() / (2 * per_row)).clamp(MIN_RUN_ROWS, n)
    };

    // Run phase: one run resident at a time — slice, stable-sort,
    // materialise, spill, drop. The spill dir is removed when `dir`
    // drops (normal return or unwind).
    let dir = SpillDir::create()?;
    let path = dir.file("sort-runs.ryf");
    let mut w = RyfWriter::create(&path)?;
    let mut lo = 0usize;
    while lo < n {
        let run = table.slice(lo, run_rows);
        let rcols: Result<Vec<&Column>> = keys
            .iter()
            .map(|k| run.column_by_name(&k.column))
            .collect();
        let perm = argsort_by_columns(&rcols?, desc, run.num_rows());
        let sorted =
            take_parallel(&run, &perm, exec::parallelism_for(perm.len()));
        exec::note_spill(sorted.byte_size() as u64);
        w.append(&sorted)?;
        lo += run.num_rows();
    }
    w.finish()?;

    // Merge phase: read the sorted runs back and merge their index
    // ranges stably (ties take the earlier run). Runs are contiguous
    // pieces of the input in original order and each is stably sorted,
    // so the merged order is exactly the serial stable argsort's.
    let metas = read_ryf_footer(&path)?;
    let mut parts = Vec::with_capacity(metas.len());
    for m in &metas {
        parts.push(read_ryf_group(&path, m)?);
    }
    let concat = Table::concat_all(table.schema(), &parts)?;
    let runs: Vec<Vec<usize>> = {
        let mut runs = Vec::with_capacity(parts.len());
        let mut lo = 0usize;
        for p in &parts {
            runs.push((lo..lo + p.num_rows()).collect());
            lo += p.num_rows();
        }
        runs
    };
    drop(parts);
    let ccols: Result<Vec<&Column>> = keys
        .iter()
        .map(|k| concat.column_by_name(&k.column))
        .collect();
    let ccols = ccols?;
    let cmp = |a: usize, b: usize| -> Ordering {
        for (c, &d) in ccols.iter().zip(desc) {
            let ord = c.cmp_rows(a, *c, b);
            let ord = if d { ord.reverse() } else { ord };
            if ord != Ordering::Equal {
                return ord;
            }
        }
        Ordering::Equal
    };
    let perm = merge_runs_stable_by(runs, |&b, &a| cmp(b, a) == Ordering::Less);
    Ok(take_parallel(
        &concat,
        &perm,
        exec::parallelism_for(perm.len()),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_opt_i64(vec![Some(3), None, Some(1), Some(3)])),
            ("v", Column::from_str(&["x", "y", "z", "w"])),
        ])
        .unwrap()
    }

    #[test]
    fn single_key_asc_radix_path() {
        let s = orderby(&t(), &[SortKey::asc("k")]).unwrap();
        // Nulls first, then 1, 3, 3 (stable: "x" before "w").
        assert!(s.column(0).value(0).is_null());
        assert_eq!(s.column(0).i64_values()[1..], [1, 3, 3]);
        assert_eq!(s.column(1).value(2).as_str(), Some("x"));
        assert_eq!(s.column(1).value(3).as_str(), Some("w"));
    }

    #[test]
    fn descending() {
        let s = orderby(&t(), &[SortKey::desc("k")]).unwrap();
        assert_eq!(s.column(0).i64_values()[..3], [3, 3, 1]);
        assert!(s.column(0).value(3).is_null());
    }

    #[test]
    fn multi_key_tiebreak() {
        let s =
            orderby(&t(), &[SortKey::asc("k"), SortKey::desc("v")]).unwrap();
        // k=3 run ordered by v desc: "x" then "w".
        assert_eq!(s.column(1).value(2).as_str(), Some("x"));
        assert_eq!(s.column(1).value(3).as_str(), Some("w"));
    }

    #[test]
    fn empty_keys_identity() {
        let s = orderby(&t(), &[]).unwrap();
        assert_eq!(s, t());
    }

    #[test]
    fn missing_column() {
        assert!(orderby(&t(), &[SortKey::asc("ghost")]).is_err());
    }

    fn random_table(seed: u64, n: usize) -> Table {
        let mut rng = crate::util::rng::Xoshiro256::new(seed);
        let k: Vec<Option<i64>> = (0..n)
            .map(|_| {
                if rng.next_below(9) == 0 {
                    None
                } else {
                    Some(rng.next_below(50) as i64)
                }
            })
            .collect();
        let s: Vec<String> =
            (0..n).map(|_| format!("s{}", rng.next_below(7))).collect();
        Table::from_columns(vec![
            ("k", Column::from_opt_i64(k)),
            ("s", Column::from_str(
                &s.iter().map(|x| x.as_str()).collect::<Vec<_>>(),
            )),
            ("v", Column::from_i64((0..n as i64).collect())),
        ])
        .unwrap()
    }

    #[test]
    fn external_sort_bit_identical_to_in_memory() {
        let t = random_table(31, 3000);
        for keys in [
            vec![SortKey::asc("k")], // radix fast path oracle
            vec![SortKey::desc("k"), SortKey::asc("s")],
            vec![SortKey::asc("s"), SortKey::desc("v")],
        ] {
            let oracle = orderby(&t, &keys).unwrap();
            // A 1-byte budget floors the run size at MIN_RUN_ROWS →
            // many runs, real merging.
            let spilled = exec::with_memory_budget_bytes(1, || {
                orderby(&t, &keys).unwrap()
            });
            assert_eq!(spilled, oracle);
        }
    }

    #[test]
    fn external_sort_spills_and_cleans_up() {
        let t = random_table(32, 2000);
        let dirs = exec::live_spill_dirs();
        let (bytes, parts) =
            (exec::spill_bytes(), exec::spill_partitions());
        exec::with_memory_budget_bytes(1, || {
            orderby(&t, &[SortKey::asc("k")]).unwrap();
        });
        assert!(exec::spill_bytes() > bytes, "runs must hit disk");
        assert!(exec::spill_partitions() > parts);
        assert_eq!(exec::live_spill_dirs(), dirs, "no leaked spill dirs");
    }
}
