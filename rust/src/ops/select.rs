//! Select — "produce another table by selecting a set of attributes
//! matching a predicate function that works on individual records"
//! (Table I).
//!
//! Two predicate forms:
//! * [`Predicate`] — typed columnar comparisons (`col <op> literal`,
//!   AND/OR/NOT) evaluated column-at-a-time without boxing; this is the
//!   hot path and what the CLI/pipeline expression syntax compiles to.
//! * a closure over boxed rows (`select_rows`) for arbitrary logic —
//!   the binding-layer/notebook convenience, paying the boxing cost.

use crate::column::Column;
use crate::compute::filter::{filter_indices, filter_table, take_parallel};
use crate::error::{Result, RylonError};
use crate::exec;
use crate::table::Table;
use crate::types::Value;

/// Comparison operator in a columnar predicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    fn eval(&self, ord: std::cmp::Ordering) -> bool {
        use std::cmp::Ordering::*;
        matches!(
            (self, ord),
            (CmpOp::Eq, Equal)
                | (CmpOp::Ne, Less)
                | (CmpOp::Ne, Greater)
                | (CmpOp::Lt, Less)
                | (CmpOp::Le, Less)
                | (CmpOp::Le, Equal)
                | (CmpOp::Gt, Greater)
                | (CmpOp::Ge, Greater)
                | (CmpOp::Ge, Equal)
        )
    }
}

/// A boolean expression over one table's columns.
#[derive(Debug, Clone)]
pub enum Predicate {
    /// `column <op> literal`; null cells never match (SQL three-valued
    /// logic collapsed to false).
    Cmp {
        column: String,
        op: CmpOp,
        literal: Value,
    },
    /// Column is null / not null.
    IsNull { column: String, negated: bool },
    And(Box<Predicate>, Box<Predicate>),
    Or(Box<Predicate>, Box<Predicate>),
    Not(Box<Predicate>),
}

impl Predicate {
    pub fn cmp(column: impl Into<String>, op: CmpOp, literal: impl Into<Value>) -> Predicate {
        Predicate::Cmp {
            column: column.into(),
            op,
            literal: literal.into(),
        }
    }

    pub fn and(self, other: Predicate) -> Predicate {
        Predicate::And(Box::new(self), Box::new(other))
    }

    pub fn or(self, other: Predicate) -> Predicate {
        Predicate::Or(Box::new(self), Box::new(other))
    }

    pub fn not(self) -> Predicate {
        Predicate::Not(Box::new(self))
    }

    /// Evaluate to a per-row boolean mask. Large tables evaluate one
    /// range per worker, split [`exec::split_width`]-wide — the steal
    /// group's capacity, not just the local budget, so a serial-budget
    /// rank's ranges are still claimable by idle sibling workers.
    /// Results are concatenated in range order, so the mask is
    /// bit-identical to a serial evaluation at any width.
    pub fn eval_mask(&self, table: &Table) -> Result<Vec<bool>> {
        let n = table.num_rows();
        let exec = exec::parallelism_for(n);
        let width = exec::split_width(exec);
        if n >= exec::par_row_threshold()
            && exec::morsel_parallel(exec)
            && width > 1
        {
            let parts = exec::map_parallel_budgeted(
                exec::split_even(n, width),
                |m| self.eval_mask_range(table, m.start, m.end),
            );
            let mut out = Vec::with_capacity(n);
            for p in parts {
                out.extend(p?);
            }
            return Ok(out);
        }
        self.eval_mask_range(table, 0, n)
    }

    /// Evaluate the predicate over rows `[start, end)`; the returned
    /// mask is indexed relative to `start`.
    pub fn eval_mask_range(
        &self,
        table: &Table,
        start: usize,
        end: usize,
    ) -> Result<Vec<bool>> {
        match self {
            Predicate::Cmp {
                column,
                op,
                literal,
            } => {
                let col = table.column_by_name(column)?;
                eval_cmp_mask_range(col, *op, literal, start, end)
            }
            Predicate::IsNull { column, negated } => {
                let col = table.column_by_name(column)?;
                Ok((start..end)
                    .map(|i| col.is_valid(i) == *negated)
                    .collect())
            }
            Predicate::And(a, b) => {
                let ma = a.eval_mask_range(table, start, end)?;
                let mb = b.eval_mask_range(table, start, end)?;
                Ok(ma.iter().zip(&mb).map(|(x, y)| *x && *y).collect())
            }
            Predicate::Or(a, b) => {
                let ma = a.eval_mask_range(table, start, end)?;
                let mb = b.eval_mask_range(table, start, end)?;
                Ok(ma.iter().zip(&mb).map(|(x, y)| *x || *y).collect())
            }
            Predicate::Not(a) => Ok(a
                .eval_mask_range(table, start, end)?
                .iter()
                .map(|x| !x)
                .collect()),
        }
    }

    /// Parse the tiny expression syntax used by the CLI and the pipeline
    /// config: `col <op> literal` with `and`/`or` (left-assoc, `and`
    /// binds tighter) — e.g. `price > 10.5 and tag == alpha`.
    pub fn parse(expr: &str) -> Result<Predicate> {
        parse_or(&mut Tokens::new(expr))
    }
}

/// Columnar comparison without per-row boxing, over rows `[start, end)`.
fn eval_cmp_mask_range(
    col: &Column,
    op: CmpOp,
    literal: &Value,
    start: usize,
    end: usize,
) -> Result<Vec<bool>> {
    let mut mask = vec![false; end - start];
    match (col, literal) {
        (Column::Int64(c), Value::Int64(x)) => {
            for (k, m) in mask.iter_mut().enumerate() {
                let i = start + k;
                if c.is_valid(i) {
                    *m = op.eval(c.value(i).cmp(x));
                }
            }
        }
        (Column::Int64(c), Value::Float64(x)) => {
            for (k, m) in mask.iter_mut().enumerate() {
                let i = start + k;
                if c.is_valid(i) {
                    *m = op.eval((c.value(i) as f64).total_cmp(x));
                }
            }
        }
        (Column::Float64(c), lit) => {
            let x = lit.as_f64().ok_or_else(|| {
                RylonError::ty(format!("compare f64 column with {lit:?}"))
            })?;
            for (k, m) in mask.iter_mut().enumerate() {
                let i = start + k;
                if c.is_valid(i) {
                    *m = op.eval(c.value(i).total_cmp(&x));
                }
            }
        }
        (Column::Utf8(c), Value::Utf8(s)) => {
            for (k, m) in mask.iter_mut().enumerate() {
                let i = start + k;
                if c.is_valid(i) {
                    *m = op.eval(c.value(i).cmp(s.as_str()));
                }
            }
        }
        (Column::Bool(c), Value::Bool(b)) => {
            for (k, m) in mask.iter_mut().enumerate() {
                let i = start + k;
                if c.is_valid(i) {
                    *m = op.eval(c.value(i).cmp(b));
                }
            }
        }
        (c, lit) => {
            return Err(RylonError::ty(format!(
                "cannot compare {} column with {:?}",
                c.dtype(),
                lit
            )))
        }
    }
    Ok(mask)
}

/// Select rows matching a columnar predicate. Mask evaluation, index
/// building and the gather all run morsel-parallel; the mask and index
/// passes split [`exec::split_width`]-wide so steal-linked sibling
/// workers can claim ranges off a serial-budget rank. Output is
/// bit-identical to a serial run.
pub fn select(table: &Table, pred: &Predicate) -> Result<Table> {
    let n = table.num_rows();
    let mask = pred.eval_mask(table)?;
    let exec = exec::parallelism_for(n);
    let width = exec::split_width(exec);
    let idx: Vec<usize> = if n >= exec::par_row_threshold()
        && exec::morsel_parallel(exec)
        && width > 1
    {
        let parts = exec::map_parallel_budgeted(
            exec::split_even(n, width),
            |m| {
                let mut v = Vec::new();
                for i in m.range() {
                    if mask[i] {
                        v.push(i);
                    }
                }
                v
            },
        );
        let mut idx = Vec::with_capacity(parts.iter().map(Vec::len).sum());
        for p in parts {
            idx.extend(p);
        }
        idx
    } else {
        filter_indices(n, |i| mask[i])
    };
    Ok(take_parallel(table, &idx, exec::parallelism_for(idx.len())))
}

/// Select rows with an arbitrary boxed-row closure (convenience path).
pub fn select_rows<F>(table: &Table, pred: F) -> Result<Table>
where
    F: FnMut(&[Value]) -> bool,
{
    filter_table(table, pred)
}

// ---- expression parser -----------------------------------------------------

struct Tokens<'a> {
    toks: Vec<&'a str>,
    pos: usize,
}

impl<'a> Tokens<'a> {
    fn new(s: &'a str) -> Tokens<'a> {
        // Pad comparison operators with spaces then whitespace-split.
        // (Literals with spaces need the programmatic API.)
        Tokens {
            toks: s.split_whitespace().collect(),
            pos: 0,
        }
    }

    fn peek(&self) -> Option<&'a str> {
        self.toks.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<&'a str> {
        let t = self.peek();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }
}

fn parse_or(t: &mut Tokens) -> Result<Predicate> {
    let mut lhs = parse_and(t)?;
    while t.peek() == Some("or") {
        t.next();
        let rhs = parse_and(t)?;
        lhs = lhs.or(rhs);
    }
    Ok(lhs)
}

fn parse_and(t: &mut Tokens) -> Result<Predicate> {
    let mut lhs = parse_atom(t)?;
    while t.peek() == Some("and") {
        t.next();
        let rhs = parse_atom(t)?;
        lhs = lhs.and(rhs);
    }
    Ok(lhs)
}

fn parse_atom(t: &mut Tokens) -> Result<Predicate> {
    let col = t
        .next()
        .ok_or_else(|| RylonError::parse("expected column name"))?;
    let op = match t.next() {
        Some("==") | Some("=") => CmpOp::Eq,
        Some("!=") => CmpOp::Ne,
        Some("<") => CmpOp::Lt,
        Some("<=") => CmpOp::Le,
        Some(">") => CmpOp::Gt,
        Some(">=") => CmpOp::Ge,
        Some("is") => {
            // `col is null` / `col is not null`
            match (t.next(), t.peek()) {
                (Some("null"), _) => {
                    return Ok(Predicate::IsNull {
                        column: col.into(),
                        negated: false,
                    })
                }
                (Some("not"), Some("null")) => {
                    t.next();
                    return Ok(Predicate::IsNull {
                        column: col.into(),
                        negated: true,
                    });
                }
                _ => return Err(RylonError::parse("expected null after is")),
            }
        }
        other => {
            return Err(RylonError::parse(format!(
                "expected comparison operator, got {other:?}"
            )))
        }
    };
    let lit = t
        .next()
        .ok_or_else(|| RylonError::parse("expected literal"))?;
    let literal = parse_literal(lit);
    Ok(Predicate::Cmp {
        column: col.into(),
        op,
        literal,
    })
}

fn parse_literal(s: &str) -> Value {
    if let Ok(v) = s.parse::<i64>() {
        return Value::Int64(v);
    }
    if let Ok(v) = s.parse::<f64>() {
        return Value::Float64(v);
    }
    match s {
        "true" => Value::Bool(true),
        "false" => Value::Bool(false),
        _ => Value::Utf8(s.trim_matches('\'').trim_matches('"').to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_i64(vec![1, 2, 3, 4])),
            (
                "price",
                Column::from_opt_f64(vec![
                    Some(5.0),
                    Some(15.0),
                    None,
                    Some(25.0),
                ]),
            ),
            ("tag", Column::from_str(&["a", "b", "a", "c"])),
        ])
        .unwrap()
    }

    #[test]
    fn cmp_predicates() {
        let t = t();
        let r = select(&t, &Predicate::cmp("price", CmpOp::Gt, 10.0)).unwrap();
        assert_eq!(r.column(0).i64_values(), &[2, 4]);
        let r = select(&t, &Predicate::cmp("tag", CmpOp::Eq, "a")).unwrap();
        assert_eq!(r.column(0).i64_values(), &[1, 3]);
        let r = select(&t, &Predicate::cmp("id", CmpOp::Le, 2i64)).unwrap();
        assert_eq!(r.num_rows(), 2);
    }

    #[test]
    fn null_cells_never_match() {
        let t = t();
        // price != 999 should still exclude the null row.
        let r =
            select(&t, &Predicate::cmp("price", CmpOp::Ne, 999.0)).unwrap();
        assert_eq!(r.column(0).i64_values(), &[1, 2, 4]);
    }

    #[test]
    fn boolean_combinators() {
        let t = t();
        let p = Predicate::cmp("price", CmpOp::Gt, 10.0)
            .and(Predicate::cmp("tag", CmpOp::Ne, "c"));
        assert_eq!(select(&t, &p).unwrap().column(0).i64_values(), &[2]);
        let p = Predicate::cmp("id", CmpOp::Eq, 1i64)
            .or(Predicate::cmp("id", CmpOp::Eq, 4i64));
        assert_eq!(select(&t, &p).unwrap().num_rows(), 2);
        let p = Predicate::cmp("tag", CmpOp::Eq, "a").not();
        assert_eq!(select(&t, &p).unwrap().column(0).i64_values(), &[2, 4]);
    }

    #[test]
    fn is_null_predicates() {
        let t = t();
        let r = select(
            &t,
            &Predicate::IsNull {
                column: "price".into(),
                negated: false,
            },
        )
        .unwrap();
        assert_eq!(r.column(0).i64_values(), &[3]);
    }

    #[test]
    fn parse_expression_syntax() {
        let t = t();
        let p = Predicate::parse("price > 10 and tag != c").unwrap();
        assert_eq!(select(&t, &p).unwrap().column(0).i64_values(), &[2]);
        let p = Predicate::parse("id == 1 or id == 4").unwrap();
        assert_eq!(select(&t, &p).unwrap().num_rows(), 2);
        let p = Predicate::parse("price is null").unwrap();
        assert_eq!(select(&t, &p).unwrap().column(0).i64_values(), &[3]);
        let p = Predicate::parse("price is not null").unwrap();
        assert_eq!(select(&t, &p).unwrap().num_rows(), 3);
        assert!(Predicate::parse("price >").is_err());
        assert!(Predicate::parse("").is_err());
    }

    #[test]
    fn int_float_cross_compare() {
        let t = t();
        let p = Predicate::cmp("id", CmpOp::Gt, 2.5);
        assert_eq!(select(&t, &p).unwrap().column(0).i64_values(), &[3, 4]);
    }

    #[test]
    fn type_errors_surface() {
        let t = t();
        assert!(select(&t, &Predicate::cmp("tag", CmpOp::Gt, 1i64)).is_err());
        assert!(select(&t, &Predicate::cmp("ghost", CmpOp::Eq, 1i64)).is_err());
    }

    #[test]
    fn select_rows_closure() {
        let t = t();
        let r = select_rows(&t, |row| {
            row[2].as_str() == Some("a") && !row[1].is_null()
        })
        .unwrap();
        assert_eq!(r.column(0).i64_values(), &[1]);
    }
}
