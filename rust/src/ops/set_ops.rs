//! Set operators over whole rows (Table I):
//!
//! * **union** — "combination of the input tables with duplicate records
//!   removed" (i.e. distinct union).
//! * **intersect** — "only the similar rows from the source tables".
//! * **difference** — "only the dissimilar rows from both tables" — the
//!   paper's wording specifies the *symmetric* difference; the one-sided
//!   [`subtract`] (A∖B) is provided as the building block.
//!
//! All three require equal arity and identical column types (names may
//! differ; output uses the left table's names). Rows compare with
//! null == null (SQL DISTINCT semantics), matching `Column::eq_rows`.

use crate::column::Column;
use crate::compute::hash::{hash_columns, HashChains};
use crate::error::{Result, RylonError};
use crate::table::Table;

/// Hash-indexed view of a table's full rows for multiset membership
/// (§Perf: pre-hashed chains, no per-bucket allocations).
struct RowIndex<'t> {
    table: &'t Table,
    cols: Vec<&'t Column>,
    chains: HashChains,
}

impl<'t> RowIndex<'t> {
    fn build(table: &'t Table, hashes: &[u64]) -> RowIndex<'t> {
        RowIndex {
            table,
            cols: table.columns().collect(),
            chains: HashChains::build(hashes, |_| false),
        }
    }

    /// Does `other[row]` (with hash `h`) exist in this table?
    fn contains(&self, other: &Table, row: usize, h: u64) -> bool {
        let ocols: Vec<&Column> = other.columns().collect();
        self.chains.bucket(h).any(|i| {
            self.cols
                .iter()
                .zip(&ocols)
                .all(|(a, b)| a.eq_rows(i, b, row))
        })
    }

    fn len_rows(&self) -> usize {
        self.table.num_rows()
    }
}

fn full_row_hashes(table: &Table) -> Vec<u64> {
    let cols: Vec<&Column> = table.columns().collect();
    let mut out = Vec::new();
    hash_columns(&cols, table.num_rows(), &mut out);
    out
}

fn check_compat(a: &Table, b: &Table) -> Result<()> {
    if !a.schema().types_match(b.schema()) {
        return Err(RylonError::schema(format!(
            "set operator requires identical column types: [{}] vs [{}]",
            a.schema(),
            b.schema()
        )));
    }
    Ok(())
}

/// Distinct rows of one table (dedup), preserving first occurrence order.
pub fn distinct(table: &Table) -> Table {
    use crate::compute::hash::{PreHashedMap, CHAIN_END};
    let hashes = full_row_hashes(table);
    let cols: Vec<&Column> = table.columns().collect();
    // Incremental chains (first-seen rows only) on pre-hashed keys.
    let mut heads: PreHashedMap<u32> = PreHashedMap::with_capacity_and_hasher(
        table.num_rows() * 2,
        Default::default(),
    );
    let mut next = vec![CHAIN_END; table.num_rows()];
    let mut keep = Vec::new();
    for (i, &h) in hashes.iter().enumerate() {
        let head = heads.entry(h).or_insert(CHAIN_END);
        let mut cur = *head;
        let mut dup = false;
        while cur != CHAIN_END {
            if cols.iter().all(|c| c.eq_rows(cur as usize, c, i)) {
                dup = true;
                break;
            }
            cur = next[cur as usize];
        }
        if !dup {
            next[i] = *head;
            *head = i as u32;
            keep.push(i);
        }
    }
    table.take(&keep)
}

/// Distinct union of two tables (Table I "Union").
pub fn union(a: &Table, b: &Table) -> Result<Table> {
    check_compat(a, b)?;
    // Concat then dedup: one pass, stable order (a's rows first).
    let both = if b.is_empty() {
        a.clone()
    } else if a.is_empty() {
        // Preserve a's schema (names) in the output.
        let renamed = Table::try_new(
            a.schema().clone(),
            b.columns().cloned().collect(),
        )?;
        renamed
    } else {
        let renamed = Table::try_new(
            a.schema().clone(),
            b.columns().cloned().collect(),
        )?;
        a.concat(&renamed)?
    };
    Ok(distinct(&both))
}

/// Distinct rows present in both tables (Table I "Intersect").
pub fn intersect(a: &Table, b: &Table) -> Result<Table> {
    check_compat(a, b)?;
    let bh = full_row_hashes(b);
    let bidx = RowIndex::build(b, &bh);
    let da = distinct(a);
    let dah = full_row_hashes(&da);
    let mut keep = Vec::new();
    for i in 0..da.num_rows() {
        if bidx.len_rows() > 0 && bidx.contains(&da, i, dah[i]) {
            keep.push(i);
        }
    }
    Ok(da.take(&keep))
}

/// Distinct rows of `a` that do not appear in `b` (one-sided A∖B).
pub fn subtract(a: &Table, b: &Table) -> Result<Table> {
    check_compat(a, b)?;
    let bh = full_row_hashes(b);
    let bidx = RowIndex::build(b, &bh);
    let da = distinct(a);
    let dah = full_row_hashes(&da);
    let mut keep = Vec::new();
    for i in 0..da.num_rows() {
        if bidx.len_rows() == 0 || !bidx.contains(&da, i, dah[i]) {
            keep.push(i);
        }
    }
    Ok(da.take(&keep))
}

/// Symmetric difference — "only the dissimilar rows from both tables"
/// (Table I "Difference"): (A∖B) ∪ (B∖A), with b's columns renamed to a's.
pub fn difference(a: &Table, b: &Table) -> Result<Table> {
    check_compat(a, b)?;
    let a_only = subtract(a, b)?;
    let b_named = Table::try_new(
        a.schema().clone(),
        b.columns().cloned().collect(),
    )?;
    let b_only = subtract(&b_named, a)?;
    a_only.concat(&b_only)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ta() -> Table {
        Table::from_columns(vec![
            ("x", Column::from_i64(vec![1, 2, 2, 3])),
            ("y", Column::from_str(&["a", "b", "b", "c"])),
        ])
        .unwrap()
    }

    fn tb() -> Table {
        Table::from_columns(vec![
            ("x", Column::from_i64(vec![2, 3, 4])),
            ("y", Column::from_str(&["b", "zzz", "d"])),
        ])
        .unwrap()
    }

    fn rows_of(t: &Table) -> Vec<(i64, String)> {
        let mut v: Vec<(i64, String)> = (0..t.num_rows())
            .map(|i| {
                (
                    t.column(0).value(i).as_i64().unwrap(),
                    t.column(1).value(i).as_str().unwrap().to_string(),
                )
            })
            .collect();
        v.sort();
        v
    }

    #[test]
    fn distinct_removes_dups_keeps_order() {
        let d = distinct(&ta());
        assert_eq!(d.num_rows(), 3);
        assert_eq!(d.column(0).i64_values(), &[1, 2, 3]);
    }

    #[test]
    fn union_dedups_across_inputs() {
        let u = union(&ta(), &tb()).unwrap();
        assert_eq!(
            rows_of(&u),
            vec![
                (1, "a".into()),
                (2, "b".into()),
                (3, "c".into()),
                (3, "zzz".into()),
                (4, "d".into()),
            ]
        );
        // Output keeps the left schema's names.
        assert_eq!(u.schema().field(0).name, "x");
    }

    #[test]
    fn intersect_full_row_semantics() {
        // (3,"c") vs (3,"zzz"): x matches but full row differs → excluded.
        let i = intersect(&ta(), &tb()).unwrap();
        assert_eq!(rows_of(&i), vec![(2, "b".into())]);
    }

    #[test]
    fn subtract_one_sided() {
        let s = subtract(&ta(), &tb()).unwrap();
        assert_eq!(rows_of(&s), vec![(1, "a".into()), (3, "c".into())]);
        let s = subtract(&tb(), &ta()).unwrap();
        assert_eq!(rows_of(&s), vec![(3, "zzz".into()), (4, "d".into())]);
    }

    #[test]
    fn difference_is_symmetric() {
        let d = difference(&ta(), &tb()).unwrap();
        assert_eq!(
            rows_of(&d),
            vec![
                (1, "a".into()),
                (3, "c".into()),
                (3, "zzz".into()),
                (4, "d".into()),
            ]
        );
        // Symmetric: same multiset either way around (names differ).
        let d2 = difference(&tb(), &ta()).unwrap();
        assert_eq!(rows_of(&d), rows_of(&d2));
    }

    #[test]
    fn type_mismatch_rejected() {
        let other = Table::from_columns(vec![
            ("x", Column::from_f64(vec![1.0])),
            ("y", Column::from_str(&["a"])),
        ])
        .unwrap();
        assert!(union(&ta(), &other).is_err());
        assert!(intersect(&ta(), &other).is_err());
        assert!(difference(&ta(), &other).is_err());
    }

    #[test]
    fn null_rows_compare_equal() {
        let a = Table::from_columns(vec![(
            "x",
            Column::from_opt_i64(vec![None, Some(1)]),
        )])
        .unwrap();
        let b = Table::from_columns(vec![(
            "x",
            Column::from_opt_i64(vec![None]),
        )])
        .unwrap();
        let i = intersect(&a, &b).unwrap();
        assert_eq!(i.num_rows(), 1);
        assert!(i.column(0).value(0).is_null());
        let s = subtract(&a, &b).unwrap();
        assert_eq!(s.num_rows(), 1);
        assert_eq!(s.column(0).value(0).as_i64(), Some(1));
    }

    #[test]
    fn empty_edge_cases() {
        let e = Table::empty(ta().schema().clone());
        assert_eq!(union(&ta(), &e).unwrap().num_rows(), 3);
        assert_eq!(union(&e, &ta()).unwrap().num_rows(), 3);
        assert_eq!(intersect(&ta(), &e).unwrap().num_rows(), 0);
        assert_eq!(subtract(&ta(), &e).unwrap().num_rows(), 3);
        assert_eq!(difference(&e, &e).unwrap().num_rows(), 0);
    }
}
