//! Project — "produce another table by selecting a subset of columns of
//! the original table" (Table I). O(columns): shares column `Arc`s, no
//! row data is touched.

use crate::error::Result;
use crate::table::Table;

/// Keep only the named columns, in the given order.
pub fn project(table: &Table, columns: &[&str]) -> Result<Table> {
    let indices: Result<Vec<usize>> = columns
        .iter()
        .map(|c| table.schema().index_of(c))
        .collect();
    let indices = indices?;
    let schema = table.schema().project(&indices);
    let cols = indices.iter().map(|&i| table.column_arc(i)).collect();
    Ok(Table::from_parts(schema, cols, table.num_rows()))
}

/// Drop the named columns, keeping everything else in order.
pub fn drop_columns(table: &Table, columns: &[&str]) -> Result<Table> {
    // Validate all names first so errors don't depend on order.
    for c in columns {
        table.schema().index_of(c)?;
    }
    let keep: Vec<&str> = table
        .schema()
        .fields()
        .iter()
        .map(|f| f.name.as_str())
        .filter(|n| !columns.contains(n))
        .collect();
    project(table, &keep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t() -> Table {
        Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 2])),
            ("b", Column::from_f64(vec![0.1, 0.2])),
            ("c", Column::from_str(&["x", "y"])),
        ])
        .unwrap()
    }

    #[test]
    fn subset_and_reorder() {
        let p = project(&t(), &["c", "a"]).unwrap();
        assert_eq!(p.num_columns(), 2);
        assert_eq!(p.schema().field(0).name, "c");
        assert_eq!(p.column(1).i64_values(), &[1, 2]);
        assert_eq!(p.num_rows(), 2);
    }

    #[test]
    fn duplicate_projection_allowed() {
        let p = project(&t(), &["a", "a"]).unwrap();
        assert_eq!(p.num_columns(), 2);
        assert_eq!(p.column(0).i64_values(), p.column(1).i64_values());
    }

    #[test]
    fn missing_column_errors() {
        assert!(project(&t(), &["ghost"]).is_err());
        assert!(drop_columns(&t(), &["ghost"]).is_err());
    }

    #[test]
    fn drop_keeps_order() {
        let d = drop_columns(&t(), &["b"]).unwrap();
        assert_eq!(
            d.schema()
                .fields()
                .iter()
                .map(|f| f.name.as_str())
                .collect::<Vec<_>>(),
            vec!["a", "c"]
        );
    }

    #[test]
    fn project_is_zero_copy() {
        let table = t();
        let p = project(&table, &["a"]).unwrap();
        // Shares the same Arc'd column.
        assert!(std::sync::Arc::ptr_eq(
            &table.column_arc(0),
            &p.column_arc(0)
        ));
    }
}
