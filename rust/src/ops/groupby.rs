//! GroupBy + aggregate — part of PyCylon's DataTable API surface (§IV).
//! Hash aggregation: group rows by key columns, fold each aggregate's
//! accumulator per group. The distributed version (dist_groupby) shuffles
//! by key then runs this locally, and for algebraic aggregates can
//! instead pre-aggregate locally and merge partials (see `dist`).
//!
//! Serial and parallel grouping share one bucket structure —
//! [`crate::compute::hash::GroupIndex`] (a [`PreHashedMap`]-backed
//! chain, the sibling of `HashChains`). The parallel path partitions
//! rows by hash prefix so each worker owns disjoint groups and feeds
//! its per-group [`Accumulator`]s in ascending row order; groups are
//! then emitted in global first-occurrence order. Output — including
//! f64 accumulation order and SQL null semantics — is bit-identical to
//! the serial path at any thread count.

use std::sync::Arc;

use crate::column::{Column, ColumnBuilder};
use crate::compute::aggregate::{Accumulator, AggKind};
use crate::compute::filter::{scatter_indices, take_parallel};
use crate::compute::hash::{hash_columns, GroupIndex};
use crate::dist::{HashPartitioner, Partitioner};
use crate::error::{Result, RylonError};
use crate::exec::{self, MemoryBudget, SpillDir};
use crate::io::ryf::{read_ryf_footer, read_ryf_group, RyfWriter};
use crate::table::Table;
use crate::types::{DataType, Field, Schema};

/// One aggregate: `kind(column) as name`.
#[derive(Debug, Clone)]
pub struct Agg {
    pub kind: AggKind,
    pub column: String,
    pub name: String,
}

impl Agg {
    pub fn new(kind: AggKind, column: &str) -> Agg {
        Agg {
            kind,
            column: column.to_string(),
            name: format!("{}_{}", kind.name(), column),
        }
    }

    pub fn named(mut self, name: &str) -> Agg {
        self.name = name.to_string();
        self
    }

    pub fn sum(column: &str) -> Agg {
        Agg::new(AggKind::Sum, column)
    }
    pub fn min(column: &str) -> Agg {
        Agg::new(AggKind::Min, column)
    }
    pub fn max(column: &str) -> Agg {
        Agg::new(AggKind::Max, column)
    }
    pub fn count(column: &str) -> Agg {
        Agg::new(AggKind::Count, column)
    }
    pub fn mean(column: &str) -> Agg {
        Agg::new(AggKind::Mean, column)
    }
}

/// GroupBy specification.
#[derive(Debug, Clone)]
pub struct GroupByOptions {
    pub keys: Vec<String>,
    pub aggs: Vec<Agg>,
}

impl GroupByOptions {
    pub fn new(keys: &[&str], aggs: Vec<Agg>) -> GroupByOptions {
        GroupByOptions {
            keys: keys.iter().map(|s| s.to_string()).collect(),
            aggs,
        }
    }
}

/// Hash group-by. Output: key columns (first occurrence order) then one
/// column per aggregate.
///
/// Consults the per-rank memory governor
/// ([`crate::exec::MemoryBudget`]): when the input's footprint doesn't
/// fit the budget, the aggregation degrades to the partitioned
/// spilling path — key-hash partitions spilled as RYF row groups and
/// aggregated one at a time — with bit-identical output
/// (`docs/MEMORY.md`).
pub fn groupby(table: &Table, opts: &GroupByOptions) -> Result<Table> {
    let budget = MemoryBudget::current();
    match budget.try_reserve(table.byte_size()) {
        Some(_held) => groupby_in_memory(table, opts),
        None if table.num_rows() > 0 => {
            validate(table, opts)?;
            spilling_groupby(table, opts, &budget)
        }
        // Empty input: nothing to spill, and the in-memory path costs
        // nothing.
        None => groupby_in_memory(table, opts),
    }
}

/// The option/schema checks [`groupby_in_memory`] performs up front,
/// extracted so the spilling path rejects invalid requests with
/// exactly the same errors before it partitions anything.
fn validate(table: &Table, opts: &GroupByOptions) -> Result<()> {
    if opts.keys.is_empty() {
        return Err(RylonError::invalid("groupby requires at least one key"));
    }
    if opts.aggs.is_empty() {
        return Err(RylonError::invalid(
            "groupby requires at least one aggregate",
        ));
    }
    for k in &opts.keys {
        table.column_by_name(k)?;
    }
    for a in &opts.aggs {
        let c = table.column_by_name(&a.column)?;
        a.kind.output_dtype(c.dtype())?;
    }
    Ok(())
}

fn groupby_in_memory(table: &Table, opts: &GroupByOptions) -> Result<Table> {
    if opts.keys.is_empty() {
        return Err(RylonError::invalid("groupby requires at least one key"));
    }
    if opts.aggs.is_empty() {
        return Err(RylonError::invalid(
            "groupby requires at least one aggregate",
        ));
    }
    let key_cols: Result<Vec<&Column>> = opts
        .keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect();
    let key_cols = key_cols?;
    let agg_cols: Result<Vec<&Column>> = opts
        .aggs
        .iter()
        .map(|a| table.column_by_name(&a.column))
        .collect();
    let agg_cols = agg_cols?;
    // Validate output dtypes up front.
    let out_dtypes: Result<Vec<_>> = opts
        .aggs
        .iter()
        .zip(&agg_cols)
        .map(|(a, c)| a.kind.output_dtype(c.dtype()))
        .collect();
    let out_dtypes = out_dtypes?;

    let mut hashes = Vec::new();
    hash_columns(&key_cols, table.num_rows(), &mut hashes);

    let new_acc_row = || -> Vec<Accumulator> {
        opts.aggs
            .iter()
            .zip(&agg_cols)
            .map(|(a, c)| {
                a.kind
                    .new_acc(c.dtype() == crate::types::DataType::Int64)
            })
            .collect()
    };
    let keys_eq = |rep: usize, row: usize| -> bool {
        key_cols.iter().all(|c| c.eq_rows(rep, c, row))
    };

    let exec = exec::parallelism_for(table.num_rows());
    // (rep_row, accumulators) per group, in global first-occurrence
    // order — identical between the serial and parallel paths.
    let (rep_rows, accs): (Vec<usize>, Vec<Vec<Accumulator>>) =
        if exec.is_parallel() {
            // Radix-partition rows by hash prefix: a group's rows all
            // share one hash, so each partition owns whole groups and
            // no cross-partition accumulator merge is needed. A single
            // O(n) prepass buckets row ids per partition; each worker
            // then touches only its own rows, in ascending row order
            // (morsel-major), matching the serial fold order exactly.
            let nparts = exec.threads();
            let rows_by_part = crate::compute::hash::partition_rows(
                &hashes,
                nparts,
                exec,
                |_| false,
            );
            let parts = exec::run_partitions(nparts, |p| {
                let mut gi = GroupIndex::with_capacity(
                    table.num_rows() / nparts + 8,
                );
                let mut part_accs: Vec<Vec<Accumulator>> = Vec::new();
                for morsel_buckets in &rows_by_part {
                    for &row in &morsel_buckets[p] {
                        let i = row as usize;
                        let (gid, new) = gi.intern(hashes[i], i, keys_eq);
                        if new {
                            part_accs.push(new_acc_row());
                        }
                        for (acc, col) in
                            part_accs[gid as usize].iter_mut().zip(&agg_cols)
                        {
                            acc.update(col, i);
                        }
                    }
                }
                (gi, part_accs)
            });
            // Serial group ids are assigned at first occurrence, so the
            // serial group order is ascending representative row —
            // recover it by sorting the per-partition groups.
            let mut order: Vec<(usize, usize, usize)> = Vec::new();
            for (p, (gi, _)) in parts.iter().enumerate() {
                for (g, &rep) in gi.rep_rows().iter().enumerate() {
                    order.push((rep, p, g));
                }
            }
            order.sort_unstable();
            let mut parts_accs: Vec<Vec<Option<Vec<Accumulator>>>> = parts
                .into_iter()
                .map(|(_, a)| a.into_iter().map(Some).collect())
                .collect();
            let mut rep_rows = Vec::with_capacity(order.len());
            let mut accs = Vec::with_capacity(order.len());
            for &(rep, p, g) in &order {
                rep_rows.push(rep);
                accs.push(
                    parts_accs[p][g].take().expect("group consumed twice"),
                );
            }
            (rep_rows, accs)
        } else {
            let mut gi = GroupIndex::with_capacity(table.num_rows());
            let mut accs: Vec<Vec<Accumulator>> = Vec::new();
            for (i, &h) in hashes.iter().enumerate() {
                let (gid, new) = gi.intern(h, i, keys_eq);
                if new {
                    accs.push(new_acc_row());
                }
                for (acc, col) in
                    accs[gid as usize].iter_mut().zip(&agg_cols)
                {
                    acc.update(col, i);
                }
            }
            (gi.rep_rows().to_vec(), accs)
        };

    // Assemble output.
    let ngroups = rep_rows.len();
    let mut fields: Vec<Field> = Vec::new();
    let mut out_cols: Vec<Column> = Vec::new();
    for (k, kc) in opts.keys.iter().zip(&key_cols) {
        fields.push(Field::new(k.clone(), kc.dtype()));
        out_cols.push(kc.take(&rep_rows));
    }
    for ((agg, dt), slot) in
        opts.aggs.iter().zip(out_dtypes).zip(0..opts.aggs.len())
    {
        fields.push(Field::new(agg.name.clone(), dt));
        let mut b = ColumnBuilder::new(dt, ngroups);
        for acc_row in &accs {
            b.push_value(&acc_row[slot].finish())?;
        }
        out_cols.push(b.finish());
    }
    Table::try_new(Schema::new(fields), out_cols)
}

/// Synthetic column carrying each row's original row id through the
/// spilling path; `min(id)` per group is the group's global
/// first-occurrence row, which restores the in-memory output order.
const SPILL_REP: &str = "__rylon_spill_rep__";

/// Partition counts per spill level — pairwise coprime so a recursive
/// level's `hash % nparts` actually re-splits (same scheme as the
/// grace hash join's).
const SPILL_PARTS: [usize; 4] = [8, 11, 13, 17];

/// Recursion ceiling; past it an unsplittable partition (one dominant
/// key) is aggregated in memory regardless of the budget.
const MAX_SPILL_DEPTH: usize = SPILL_PARTS.len() - 1;

/// Out-of-core twin of [`groupby_in_memory`]: identical output,
/// O(partition) resident memory instead of O(input). Rows are routed
/// by the combined key hash (equal hashes share a partition, and a
/// group is "same hash + equal keys", so every group is whole within
/// one partition), gathered in ascending row order (so accumulator
/// fold order — including f64 bit patterns — matches the serial
/// path), spilled as RYF row groups, and aggregated one partition at a
/// time. A min-aggregated row-id column recovers the global
/// first-occurrence group order at the end.
fn spilling_groupby(
    table: &Table,
    opts: &GroupByOptions,
    budget: &MemoryBudget,
) -> Result<Table> {
    let n = table.num_rows();
    // Augment with the row-id column and its min-aggregate.
    let mut aug_cols: Vec<Arc<Column>> =
        (0..table.num_columns()).map(|i| table.column_arc(i)).collect();
    aug_cols.push(Arc::new(Column::from_i64((0..n as i64).collect())));
    let mut aug_fields = table.schema().fields().to_vec();
    aug_fields.push(Field::new(SPILL_REP.to_string(), DataType::Int64));
    let aug = Table::from_parts(Schema::new(aug_fields), aug_cols, n);
    let mut aug_opts = opts.clone();
    aug_opts.aggs.push(Agg::min(SPILL_REP).named(SPILL_REP));

    let grouped = spill_level(&aug, &aug_opts, budget, 0)?;

    // Restore global first-occurrence order and strip the rep column.
    let rep_idx = grouped.num_columns() - 1;
    let reps = grouped.column(rep_idx).i64_values();
    let mut perm: Vec<usize> = (0..grouped.num_rows()).collect();
    perm.sort_unstable_by_key(|&i| reps[i]);
    let ordered = take_parallel(
        &grouped,
        &perm,
        exec::parallelism_for(perm.len()),
    );
    let out_fields = ordered.schema().fields()[..rep_idx].to_vec();
    let out_cols: Vec<Arc<Column>> =
        (0..rep_idx).map(|i| ordered.column_arc(i)).collect();
    Ok(Table::from_parts(
        Schema::new(out_fields),
        out_cols,
        ordered.num_rows(),
    ))
}

/// One spill level: partition `aug` by key hash, spill each partition
/// as an RYF row group under a per-level [`SpillDir`] (deleted when
/// the dir drops — normal return or unwind), then aggregate the
/// partitions one at a time, recursing when a partition still doesn't
/// fit and can still split. Partial group order is irrelevant here —
/// the caller sorts by the rep column.
fn spill_level(
    aug: &Table,
    aug_opts: &GroupByOptions,
    budget: &MemoryBudget,
    depth: usize,
) -> Result<Table> {
    let nparts = SPILL_PARTS[depth.min(MAX_SPILL_DEPTH)];
    let mut pids = Vec::new();
    HashPartitioner::new(&aug_opts.keys, nparts)?.partition(aug, &mut pids)?;
    let rows = scatter_indices(&pids, nparts);
    drop(pids);

    let dir = SpillDir::create()?;
    let path = dir.file("groupby.ryf");
    let mut w = RyfWriter::create(&path)?;
    for part_rows in &rows {
        let part = take_parallel(
            aug,
            part_rows,
            exec::parallelism_for(part_rows.len()),
        );
        exec::note_spill(part.byte_size() as u64);
        w.append(&part)?;
    }
    w.finish()?;
    drop(rows);

    let metas = read_ryf_footer(&path)?;
    let mut partials: Vec<Table> = Vec::with_capacity(nparts);
    for meta in &metas {
        let sub = read_ryf_group(&path, meta)?;
        if sub.num_rows() == 0 {
            continue;
        }
        let splittable =
            depth < MAX_SPILL_DEPTH && sub.num_rows() < aug.num_rows();
        let partial = match budget.try_reserve(sub.byte_size()) {
            Some(_held) => groupby_in_memory(&sub, aug_opts)?,
            None if splittable => {
                spill_level(&sub, aug_opts, budget, depth + 1)?
            }
            None => groupby_in_memory(&sub, aug_opts)?,
        };
        partials.push(partial);
    }
    match partials.first() {
        Some(first) => {
            let schema = first.schema().clone();
            Table::concat_all(&schema, &partials)
        }
        // Unreachable for non-empty input, but keep it total.
        None => groupby_in_memory(
            &Table::empty(aug.schema().clone()),
            aug_opts,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_str(&["a", "b", "a", "b", "a"])),
            (
                "v",
                Column::from_opt_i64(vec![
                    Some(1),
                    Some(10),
                    Some(2),
                    None,
                    Some(3),
                ]),
            ),
        ])
        .unwrap()
    }

    fn find_group(g: &Table, key: &str) -> usize {
        (0..g.num_rows())
            .find(|&i| g.column(0).value(i) == Value::Utf8(key.into()))
            .unwrap()
    }

    #[test]
    fn sum_count_mean_per_group() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(
                &["k"],
                vec![Agg::sum("v"), Agg::count("v"), Agg::mean("v")],
            ),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        let a = find_group(&g, "a");
        let b = find_group(&g, "b");
        assert_eq!(g.column(1).value(a), Value::Int64(6));
        assert_eq!(g.column(2).value(a), Value::Int64(3));
        assert_eq!(g.column(3).value(a), Value::Float64(2.0));
        // Group b: one null skipped.
        assert_eq!(g.column(1).value(b), Value::Int64(10));
        assert_eq!(g.column(2).value(b), Value::Int64(1));
    }

    #[test]
    fn output_schema_names() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(
                &["k"],
                vec![Agg::max("v").named("vmax")],
            ),
        )
        .unwrap();
        assert_eq!(g.schema().field(0).name, "k");
        assert_eq!(g.schema().field(1).name, "vmax");
    }

    #[test]
    fn multi_key_groups() {
        let t = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2, 1])),
            ("b", Column::from_i64(vec![1, 2, 1, 1])),
            ("v", Column::from_f64(vec![1.0, 2.0, 3.0, 4.0])),
        ])
        .unwrap();
        let g = groupby(
            &t,
            &GroupByOptions::new(&["a", "b"], vec![Agg::sum("v")]),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 3);
        let i = (0..3)
            .find(|&i| {
                g.column(0).value(i) == Value::Int64(1)
                    && g.column(1).value(i) == Value::Int64(1)
            })
            .unwrap();
        assert_eq!(g.column(2).value(i), Value::Float64(5.0));
    }

    #[test]
    fn null_keys_form_a_group() {
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(vec![None, None, Some(1)])),
            ("v", Column::from_i64(vec![5, 6, 7])),
        ])
        .unwrap();
        let g = groupby(
            &t,
            &GroupByOptions::new(&["k"], vec![Agg::sum("v")]),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        let nidx = (0..2).find(|&i| g.column(0).value(i).is_null()).unwrap();
        assert_eq!(g.column(1).value(nidx), Value::Int64(11));
    }

    #[test]
    fn validation() {
        assert!(groupby(&t(), &GroupByOptions::new(&[], vec![Agg::sum("v")]))
            .is_err());
        assert!(groupby(&t(), &GroupByOptions::new(&["k"], vec![])).is_err());
        assert!(groupby(
            &t(),
            &GroupByOptions::new(&["k"], vec![Agg::sum("k")])
        )
        .is_err()); // sum over strings
        assert!(groupby(
            &t(),
            &GroupByOptions::new(&["ghost"], vec![Agg::sum("v")])
        )
        .is_err());
    }

    #[test]
    fn parallel_groupby_bit_identical() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(99);
        let n = 20_000usize;
        let keys: Vec<Option<i64>> = (0..n)
            .map(|_| {
                if rng.next_below(13) == 0 {
                    None
                } else {
                    Some(rng.next_below(500) as i64)
                }
            })
            .collect();
        let vals: Vec<Option<f64>> = (0..n)
            .map(|_| {
                if rng.next_below(9) == 0 {
                    None
                } else {
                    Some(rng.next_f64() * 100.0 - 50.0)
                }
            })
            .collect();
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(keys)),
            ("v", Column::from_opt_f64(vals)),
        ])
        .unwrap();
        let opts = GroupByOptions::new(
            &["k"],
            vec![
                Agg::sum("v"),
                Agg::count("v"),
                Agg::mean("v"),
                Agg::min("v"),
                Agg::max("v"),
            ],
        );
        let serial = groupby(&t, &opts).unwrap();
        for threads in [2, 4, 7] {
            let par = crate::exec::with_intra_op_threads(threads, || {
                groupby(&t, &opts).unwrap()
            });
            // Table equality is value equality — including group order
            // and f64 bits accumulated in the same fold order.
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn spilling_groupby_bit_identical_and_cleans_up() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(123);
        let n = 5_000usize;
        let keys: Vec<Option<i64>> = (0..n)
            .map(|_| {
                if rng.next_below(13) == 0 {
                    None
                } else {
                    Some(rng.next_below(200) as i64)
                }
            })
            .collect();
        let vals: Vec<Option<f64>> = (0..n)
            .map(|_| {
                if rng.next_below(9) == 0 {
                    None
                } else {
                    Some(rng.next_f64() * 100.0 - 50.0)
                }
            })
            .collect();
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(keys)),
            ("v", Column::from_opt_f64(vals)),
        ])
        .unwrap();
        let opts = GroupByOptions::new(
            &["k"],
            vec![Agg::sum("v"), Agg::mean("v"), Agg::count("v")],
        );
        let oracle = groupby(&t, &opts).unwrap();
        let dirs = exec::live_spill_dirs();
        let parts0 = exec::spill_partitions();
        // Tiny budget: recursive re-partitioning down to the depth cap.
        let tiny = crate::exec::with_memory_budget_bytes(1, || {
            groupby(&t, &opts).unwrap()
        });
        assert_eq!(tiny, oracle, "recursive spill");
        // Half the footprint: one spill level, partitions aggregated
        // in memory.
        let half = crate::exec::with_memory_budget_bytes(
            t.byte_size() / 2,
            || groupby(&t, &opts).unwrap(),
        );
        assert_eq!(half, oracle, "one spill level");
        assert!(exec::spill_partitions() > parts0, "partitions hit disk");
        assert_eq!(exec::live_spill_dirs(), dirs, "no leaked spill dirs");
        // Invalid requests fail identically under a spill-forcing
        // budget (validation happens before any partitioning).
        crate::exec::with_memory_budget_bytes(1, || {
            assert!(groupby(&t, &GroupByOptions::new(&["k"], vec![]))
                .is_err());
            assert!(groupby(
                &t,
                &GroupByOptions::new(&["ghost"], vec![Agg::sum("v")])
            )
            .is_err());
        });
    }

    #[test]
    fn min_max_over_strings() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(&["k"], vec![Agg::min("k"), Agg::max("k")]),
        )
        .unwrap();
        let a = find_group(&g, "a");
        assert_eq!(g.column(1).value(a), Value::Utf8("a".into()));
    }
}
