//! GroupBy + aggregate — part of PyCylon's DataTable API surface (§IV).
//! Hash aggregation: group rows by key columns, fold each aggregate's
//! accumulator per group. The distributed version (dist_groupby) shuffles
//! by key then runs this locally, and for algebraic aggregates can
//! instead pre-aggregate locally and merge partials (see `dist`).
//!
//! Serial and parallel grouping share one bucket structure —
//! [`crate::compute::hash::GroupIndex`] (a [`PreHashedMap`]-backed
//! chain, the sibling of `HashChains`). The parallel path partitions
//! rows by hash prefix so each worker owns disjoint groups and feeds
//! its per-group [`Accumulator`]s in ascending row order; groups are
//! then emitted in global first-occurrence order. Output — including
//! f64 accumulation order and SQL null semantics — is bit-identical to
//! the serial path at any thread count.

use crate::column::{Column, ColumnBuilder};
use crate::compute::aggregate::{Accumulator, AggKind};
use crate::compute::hash::{hash_columns, GroupIndex};
use crate::error::{Result, RylonError};
use crate::exec;
use crate::table::Table;
use crate::types::{Field, Schema};

/// One aggregate: `kind(column) as name`.
#[derive(Debug, Clone)]
pub struct Agg {
    pub kind: AggKind,
    pub column: String,
    pub name: String,
}

impl Agg {
    pub fn new(kind: AggKind, column: &str) -> Agg {
        Agg {
            kind,
            column: column.to_string(),
            name: format!("{}_{}", kind.name(), column),
        }
    }

    pub fn named(mut self, name: &str) -> Agg {
        self.name = name.to_string();
        self
    }

    pub fn sum(column: &str) -> Agg {
        Agg::new(AggKind::Sum, column)
    }
    pub fn min(column: &str) -> Agg {
        Agg::new(AggKind::Min, column)
    }
    pub fn max(column: &str) -> Agg {
        Agg::new(AggKind::Max, column)
    }
    pub fn count(column: &str) -> Agg {
        Agg::new(AggKind::Count, column)
    }
    pub fn mean(column: &str) -> Agg {
        Agg::new(AggKind::Mean, column)
    }
}

/// GroupBy specification.
#[derive(Debug, Clone)]
pub struct GroupByOptions {
    pub keys: Vec<String>,
    pub aggs: Vec<Agg>,
}

impl GroupByOptions {
    pub fn new(keys: &[&str], aggs: Vec<Agg>) -> GroupByOptions {
        GroupByOptions {
            keys: keys.iter().map(|s| s.to_string()).collect(),
            aggs,
        }
    }
}

/// Hash group-by. Output: key columns (first occurrence order) then one
/// column per aggregate.
pub fn groupby(table: &Table, opts: &GroupByOptions) -> Result<Table> {
    if opts.keys.is_empty() {
        return Err(RylonError::invalid("groupby requires at least one key"));
    }
    if opts.aggs.is_empty() {
        return Err(RylonError::invalid(
            "groupby requires at least one aggregate",
        ));
    }
    let key_cols: Result<Vec<&Column>> = opts
        .keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect();
    let key_cols = key_cols?;
    let agg_cols: Result<Vec<&Column>> = opts
        .aggs
        .iter()
        .map(|a| table.column_by_name(&a.column))
        .collect();
    let agg_cols = agg_cols?;
    // Validate output dtypes up front.
    let out_dtypes: Result<Vec<_>> = opts
        .aggs
        .iter()
        .zip(&agg_cols)
        .map(|(a, c)| a.kind.output_dtype(c.dtype()))
        .collect();
    let out_dtypes = out_dtypes?;

    let mut hashes = Vec::new();
    hash_columns(&key_cols, table.num_rows(), &mut hashes);

    let new_acc_row = || -> Vec<Accumulator> {
        opts.aggs
            .iter()
            .zip(&agg_cols)
            .map(|(a, c)| {
                a.kind
                    .new_acc(c.dtype() == crate::types::DataType::Int64)
            })
            .collect()
    };
    let keys_eq = |rep: usize, row: usize| -> bool {
        key_cols.iter().all(|c| c.eq_rows(rep, c, row))
    };

    let exec = exec::parallelism_for(table.num_rows());
    // (rep_row, accumulators) per group, in global first-occurrence
    // order — identical between the serial and parallel paths.
    let (rep_rows, accs): (Vec<usize>, Vec<Vec<Accumulator>>) =
        if exec.is_parallel() {
            // Radix-partition rows by hash prefix: a group's rows all
            // share one hash, so each partition owns whole groups and
            // no cross-partition accumulator merge is needed. A single
            // O(n) prepass buckets row ids per partition; each worker
            // then touches only its own rows, in ascending row order
            // (morsel-major), matching the serial fold order exactly.
            let nparts = exec.threads();
            let rows_by_part = crate::compute::hash::partition_rows(
                &hashes,
                nparts,
                exec,
                |_| false,
            );
            let parts = exec::run_partitions(nparts, |p| {
                let mut gi = GroupIndex::with_capacity(
                    table.num_rows() / nparts + 8,
                );
                let mut part_accs: Vec<Vec<Accumulator>> = Vec::new();
                for morsel_buckets in &rows_by_part {
                    for &row in &morsel_buckets[p] {
                        let i = row as usize;
                        let (gid, new) = gi.intern(hashes[i], i, keys_eq);
                        if new {
                            part_accs.push(new_acc_row());
                        }
                        for (acc, col) in
                            part_accs[gid as usize].iter_mut().zip(&agg_cols)
                        {
                            acc.update(col, i);
                        }
                    }
                }
                (gi, part_accs)
            });
            // Serial group ids are assigned at first occurrence, so the
            // serial group order is ascending representative row —
            // recover it by sorting the per-partition groups.
            let mut order: Vec<(usize, usize, usize)> = Vec::new();
            for (p, (gi, _)) in parts.iter().enumerate() {
                for (g, &rep) in gi.rep_rows().iter().enumerate() {
                    order.push((rep, p, g));
                }
            }
            order.sort_unstable();
            let mut parts_accs: Vec<Vec<Option<Vec<Accumulator>>>> = parts
                .into_iter()
                .map(|(_, a)| a.into_iter().map(Some).collect())
                .collect();
            let mut rep_rows = Vec::with_capacity(order.len());
            let mut accs = Vec::with_capacity(order.len());
            for &(rep, p, g) in &order {
                rep_rows.push(rep);
                accs.push(
                    parts_accs[p][g].take().expect("group consumed twice"),
                );
            }
            (rep_rows, accs)
        } else {
            let mut gi = GroupIndex::with_capacity(table.num_rows());
            let mut accs: Vec<Vec<Accumulator>> = Vec::new();
            for (i, &h) in hashes.iter().enumerate() {
                let (gid, new) = gi.intern(h, i, keys_eq);
                if new {
                    accs.push(new_acc_row());
                }
                for (acc, col) in
                    accs[gid as usize].iter_mut().zip(&agg_cols)
                {
                    acc.update(col, i);
                }
            }
            (gi.rep_rows().to_vec(), accs)
        };

    // Assemble output.
    let ngroups = rep_rows.len();
    let mut fields: Vec<Field> = Vec::new();
    let mut out_cols: Vec<Column> = Vec::new();
    for (k, kc) in opts.keys.iter().zip(&key_cols) {
        fields.push(Field::new(k.clone(), kc.dtype()));
        out_cols.push(kc.take(&rep_rows));
    }
    for ((agg, dt), slot) in
        opts.aggs.iter().zip(out_dtypes).zip(0..opts.aggs.len())
    {
        fields.push(Field::new(agg.name.clone(), dt));
        let mut b = ColumnBuilder::new(dt, ngroups);
        for acc_row in &accs {
            b.push_value(&acc_row[slot].finish())?;
        }
        out_cols.push(b.finish());
    }
    Table::try_new(Schema::new(fields), out_cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_str(&["a", "b", "a", "b", "a"])),
            (
                "v",
                Column::from_opt_i64(vec![
                    Some(1),
                    Some(10),
                    Some(2),
                    None,
                    Some(3),
                ]),
            ),
        ])
        .unwrap()
    }

    fn find_group(g: &Table, key: &str) -> usize {
        (0..g.num_rows())
            .find(|&i| g.column(0).value(i) == Value::Utf8(key.into()))
            .unwrap()
    }

    #[test]
    fn sum_count_mean_per_group() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(
                &["k"],
                vec![Agg::sum("v"), Agg::count("v"), Agg::mean("v")],
            ),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        let a = find_group(&g, "a");
        let b = find_group(&g, "b");
        assert_eq!(g.column(1).value(a), Value::Int64(6));
        assert_eq!(g.column(2).value(a), Value::Int64(3));
        assert_eq!(g.column(3).value(a), Value::Float64(2.0));
        // Group b: one null skipped.
        assert_eq!(g.column(1).value(b), Value::Int64(10));
        assert_eq!(g.column(2).value(b), Value::Int64(1));
    }

    #[test]
    fn output_schema_names() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(
                &["k"],
                vec![Agg::max("v").named("vmax")],
            ),
        )
        .unwrap();
        assert_eq!(g.schema().field(0).name, "k");
        assert_eq!(g.schema().field(1).name, "vmax");
    }

    #[test]
    fn multi_key_groups() {
        let t = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2, 1])),
            ("b", Column::from_i64(vec![1, 2, 1, 1])),
            ("v", Column::from_f64(vec![1.0, 2.0, 3.0, 4.0])),
        ])
        .unwrap();
        let g = groupby(
            &t,
            &GroupByOptions::new(&["a", "b"], vec![Agg::sum("v")]),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 3);
        let i = (0..3)
            .find(|&i| {
                g.column(0).value(i) == Value::Int64(1)
                    && g.column(1).value(i) == Value::Int64(1)
            })
            .unwrap();
        assert_eq!(g.column(2).value(i), Value::Float64(5.0));
    }

    #[test]
    fn null_keys_form_a_group() {
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(vec![None, None, Some(1)])),
            ("v", Column::from_i64(vec![5, 6, 7])),
        ])
        .unwrap();
        let g = groupby(
            &t,
            &GroupByOptions::new(&["k"], vec![Agg::sum("v")]),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        let nidx = (0..2).find(|&i| g.column(0).value(i).is_null()).unwrap();
        assert_eq!(g.column(1).value(nidx), Value::Int64(11));
    }

    #[test]
    fn validation() {
        assert!(groupby(&t(), &GroupByOptions::new(&[], vec![Agg::sum("v")]))
            .is_err());
        assert!(groupby(&t(), &GroupByOptions::new(&["k"], vec![])).is_err());
        assert!(groupby(
            &t(),
            &GroupByOptions::new(&["k"], vec![Agg::sum("k")])
        )
        .is_err()); // sum over strings
        assert!(groupby(
            &t(),
            &GroupByOptions::new(&["ghost"], vec![Agg::sum("v")])
        )
        .is_err());
    }

    #[test]
    fn parallel_groupby_bit_identical() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(99);
        let n = 20_000usize;
        let keys: Vec<Option<i64>> = (0..n)
            .map(|_| {
                if rng.next_below(13) == 0 {
                    None
                } else {
                    Some(rng.next_below(500) as i64)
                }
            })
            .collect();
        let vals: Vec<Option<f64>> = (0..n)
            .map(|_| {
                if rng.next_below(9) == 0 {
                    None
                } else {
                    Some(rng.next_f64() * 100.0 - 50.0)
                }
            })
            .collect();
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(keys)),
            ("v", Column::from_opt_f64(vals)),
        ])
        .unwrap();
        let opts = GroupByOptions::new(
            &["k"],
            vec![
                Agg::sum("v"),
                Agg::count("v"),
                Agg::mean("v"),
                Agg::min("v"),
                Agg::max("v"),
            ],
        );
        let serial = groupby(&t, &opts).unwrap();
        for threads in [2, 4, 7] {
            let par = crate::exec::with_intra_op_threads(threads, || {
                groupby(&t, &opts).unwrap()
            });
            // Table equality is value equality — including group order
            // and f64 bits accumulated in the same fold order.
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn min_max_over_strings() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(&["k"], vec![Agg::min("k"), Agg::max("k")]),
        )
        .unwrap();
        let a = find_group(&g, "a");
        assert_eq!(g.column(1).value(a), Value::Utf8("a".into()));
    }
}
