//! GroupBy + aggregate — part of PyCylon's DataTable API surface (§IV).
//! Hash aggregation: group rows by key columns, fold each aggregate's
//! accumulator per group. The distributed version (dist_groupby) shuffles
//! by key then runs this locally, and for algebraic aggregates can
//! instead pre-aggregate locally and merge partials (see `dist`).

use crate::column::{Column, ColumnBuilder};
use crate::compute::aggregate::{Accumulator, AggKind};
use crate::compute::hash::{hash_columns, PreHashedMap, CHAIN_END};
use crate::error::{Result, RylonError};
use crate::table::Table;
use crate::types::{Field, Schema};

/// One aggregate: `kind(column) as name`.
#[derive(Debug, Clone)]
pub struct Agg {
    pub kind: AggKind,
    pub column: String,
    pub name: String,
}

impl Agg {
    pub fn new(kind: AggKind, column: &str) -> Agg {
        Agg {
            kind,
            column: column.to_string(),
            name: format!("{}_{}", kind.name(), column),
        }
    }

    pub fn named(mut self, name: &str) -> Agg {
        self.name = name.to_string();
        self
    }

    pub fn sum(column: &str) -> Agg {
        Agg::new(AggKind::Sum, column)
    }
    pub fn min(column: &str) -> Agg {
        Agg::new(AggKind::Min, column)
    }
    pub fn max(column: &str) -> Agg {
        Agg::new(AggKind::Max, column)
    }
    pub fn count(column: &str) -> Agg {
        Agg::new(AggKind::Count, column)
    }
    pub fn mean(column: &str) -> Agg {
        Agg::new(AggKind::Mean, column)
    }
}

/// GroupBy specification.
#[derive(Debug, Clone)]
pub struct GroupByOptions {
    pub keys: Vec<String>,
    pub aggs: Vec<Agg>,
}

impl GroupByOptions {
    pub fn new(keys: &[&str], aggs: Vec<Agg>) -> GroupByOptions {
        GroupByOptions {
            keys: keys.iter().map(|s| s.to_string()).collect(),
            aggs,
        }
    }
}

/// Hash group-by. Output: key columns (first occurrence order) then one
/// column per aggregate.
pub fn groupby(table: &Table, opts: &GroupByOptions) -> Result<Table> {
    if opts.keys.is_empty() {
        return Err(RylonError::invalid("groupby requires at least one key"));
    }
    if opts.aggs.is_empty() {
        return Err(RylonError::invalid(
            "groupby requires at least one aggregate",
        ));
    }
    let key_cols: Result<Vec<&Column>> = opts
        .keys
        .iter()
        .map(|k| table.column_by_name(k))
        .collect();
    let key_cols = key_cols?;
    let agg_cols: Result<Vec<&Column>> = opts
        .aggs
        .iter()
        .map(|a| table.column_by_name(&a.column))
        .collect();
    let agg_cols = agg_cols?;
    // Validate output dtypes up front.
    let out_dtypes: Result<Vec<_>> = opts
        .aggs
        .iter()
        .zip(&agg_cols)
        .map(|(a, c)| a.kind.output_dtype(c.dtype()))
        .collect();
    let out_dtypes = out_dtypes?;

    let mut hashes = Vec::new();
    hash_columns(&key_cols, table.num_rows(), &mut hashes);

    // group id per distinct key; representative row per group (§Perf:
    // pre-hashed heads + group chain, no per-bucket Vec allocations).
    let mut heads: PreHashedMap<u32> = PreHashedMap::with_capacity_and_hasher(
        table.num_rows(),
        Default::default(),
    );
    // next_group[g] = next group id sharing the same hash bucket.
    let mut next_group: Vec<u32> = Vec::new();
    let mut rep_rows: Vec<usize> = Vec::new();
    let mut accs: Vec<Vec<Accumulator>> = Vec::new();

    for i in 0..table.num_rows() {
        let h = hashes[i];
        let head = heads.entry(h).or_insert(CHAIN_END);
        let mut cur = *head;
        let mut gid = CHAIN_END;
        while cur != CHAIN_END {
            let rep = rep_rows[cur as usize];
            if key_cols.iter().all(|c| c.eq_rows(rep, c, i)) {
                gid = cur;
                break;
            }
            cur = next_group[cur as usize];
        }
        if gid == CHAIN_END {
            gid = rep_rows.len() as u32;
            rep_rows.push(i);
            next_group.push(*head);
            *head = gid;
            accs.push(
                opts.aggs
                    .iter()
                    .zip(&agg_cols)
                    .map(|(a, c)| {
                        a.kind.new_acc(
                            c.dtype() == crate::types::DataType::Int64,
                        )
                    })
                    .collect(),
            );
        }
        for (acc, col) in accs[gid as usize].iter_mut().zip(&agg_cols) {
            acc.update(col, i);
        }
    }

    // Assemble output.
    let ngroups = rep_rows.len();
    let mut fields: Vec<Field> = Vec::new();
    let mut out_cols: Vec<Column> = Vec::new();
    for (k, kc) in opts.keys.iter().zip(&key_cols) {
        fields.push(Field::new(k.clone(), kc.dtype()));
        out_cols.push(kc.take(&rep_rows));
    }
    for ((agg, dt), slot) in
        opts.aggs.iter().zip(out_dtypes).zip(0..opts.aggs.len())
    {
        fields.push(Field::new(agg.name.clone(), dt));
        let mut b = ColumnBuilder::new(dt, ngroups);
        for acc_row in &accs {
            b.push_value(&acc_row[slot].finish())?;
        }
        out_cols.push(b.finish());
    }
    Table::try_new(Schema::new(fields), out_cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_str(&["a", "b", "a", "b", "a"])),
            (
                "v",
                Column::from_opt_i64(vec![
                    Some(1),
                    Some(10),
                    Some(2),
                    None,
                    Some(3),
                ]),
            ),
        ])
        .unwrap()
    }

    fn find_group(g: &Table, key: &str) -> usize {
        (0..g.num_rows())
            .find(|&i| g.column(0).value(i) == Value::Utf8(key.into()))
            .unwrap()
    }

    #[test]
    fn sum_count_mean_per_group() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(
                &["k"],
                vec![Agg::sum("v"), Agg::count("v"), Agg::mean("v")],
            ),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        let a = find_group(&g, "a");
        let b = find_group(&g, "b");
        assert_eq!(g.column(1).value(a), Value::Int64(6));
        assert_eq!(g.column(2).value(a), Value::Int64(3));
        assert_eq!(g.column(3).value(a), Value::Float64(2.0));
        // Group b: one null skipped.
        assert_eq!(g.column(1).value(b), Value::Int64(10));
        assert_eq!(g.column(2).value(b), Value::Int64(1));
    }

    #[test]
    fn output_schema_names() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(
                &["k"],
                vec![Agg::max("v").named("vmax")],
            ),
        )
        .unwrap();
        assert_eq!(g.schema().field(0).name, "k");
        assert_eq!(g.schema().field(1).name, "vmax");
    }

    #[test]
    fn multi_key_groups() {
        let t = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2, 1])),
            ("b", Column::from_i64(vec![1, 2, 1, 1])),
            ("v", Column::from_f64(vec![1.0, 2.0, 3.0, 4.0])),
        ])
        .unwrap();
        let g = groupby(
            &t,
            &GroupByOptions::new(&["a", "b"], vec![Agg::sum("v")]),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 3);
        let i = (0..3)
            .find(|&i| {
                g.column(0).value(i) == Value::Int64(1)
                    && g.column(1).value(i) == Value::Int64(1)
            })
            .unwrap();
        assert_eq!(g.column(2).value(i), Value::Float64(5.0));
    }

    #[test]
    fn null_keys_form_a_group() {
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(vec![None, None, Some(1)])),
            ("v", Column::from_i64(vec![5, 6, 7])),
        ])
        .unwrap();
        let g = groupby(
            &t,
            &GroupByOptions::new(&["k"], vec![Agg::sum("v")]),
        )
        .unwrap();
        assert_eq!(g.num_rows(), 2);
        let nidx = (0..2).find(|&i| g.column(0).value(i).is_null()).unwrap();
        assert_eq!(g.column(1).value(nidx), Value::Int64(11));
    }

    #[test]
    fn validation() {
        assert!(groupby(&t(), &GroupByOptions::new(&[], vec![Agg::sum("v")]))
            .is_err());
        assert!(groupby(&t(), &GroupByOptions::new(&["k"], vec![])).is_err());
        assert!(groupby(
            &t(),
            &GroupByOptions::new(&["k"], vec![Agg::sum("k")])
        )
        .is_err()); // sum over strings
        assert!(groupby(
            &t(),
            &GroupByOptions::new(&["ghost"], vec![Agg::sum("v")])
        )
        .is_err());
    }

    #[test]
    fn min_max_over_strings() {
        let g = groupby(
            &t(),
            &GroupByOptions::new(&["k"], vec![Agg::min("k"), Agg::max("k")]),
        )
        .unwrap();
        let a = find_group(&g, "a");
        assert_eq!(g.column(1).value(a), Value::Utf8("a".into()));
    }
}
