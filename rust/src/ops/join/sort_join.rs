//! Sort(-merge) join — Cylon's core join algorithm (the paper benchmarks
//! "Inner-Join (Sort)" and calls sorting "the core task in Cylon joins").
//!
//! Both sides are argsorted on their key columns (radix for single i64
//! keys, comparison sort otherwise), then a linear merge emits the cross
//! product of each equal-key run. Null-key rows are skipped by the merge
//! and re-emitted null-extended for outer joins.

use crate::column::Column;
use crate::compute::sort::{argsort_by_columns, argsort_i64};
use crate::error::Result;
use crate::ops::join::{key_columns, key_has_null, JoinOptions, JoinType};
use crate::table::Table;

/// Compute matched row-index pairs (`-1` = null-extended side).
pub fn sort_join_indices(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
) -> Result<(Vec<i64>, Vec<i64>)> {
    let lk = key_columns(left, &opts.left_on)?;
    let rk = key_columns(right, &opts.right_on)?;

    let lperm = argsort_keys(&lk, left.num_rows());
    let rperm = argsort_keys(&rk, right.num_rows());

    // Skip null-key prefixes (nulls sort first).
    let lstart = lperm
        .iter()
        .position(|&i| !key_has_null(&lk, i))
        .unwrap_or(lperm.len());
    let rstart = rperm
        .iter()
        .position(|&j| !key_has_null(&rk, j))
        .unwrap_or(rperm.len());

    let want_left_unmatched =
        matches!(opts.join_type, JoinType::Left | JoinType::FullOuter);
    let want_right_unmatched =
        matches!(opts.join_type, JoinType::Right | JoinType::FullOuter);

    let mut li: Vec<i64> = Vec::new();
    let mut ri: Vec<i64> = Vec::new();

    // §Perf: monomorphic merge for the common single-i64-key join —
    // compares raw i64s instead of enum-dispatching per row (≈2-3× on
    // the benchmark workload).
    if let ([crate::column::Column::Int64(a)], [crate::column::Column::Int64(b)]) =
        (&lk[..], &rk[..])
    {
        if want_left_unmatched {
            for &i in &lperm[..lstart] {
                li.push(i as i64);
                ri.push(-1);
            }
        }
        if want_right_unmatched {
            for &j in &rperm[..rstart] {
                li.push(-1);
                ri.push(j as i64);
            }
        }
        merge_i64(
            a.values(),
            b.values(),
            &lperm[lstart..],
            &rperm[rstart..],
            want_left_unmatched,
            want_right_unmatched,
            &mut li,
            &mut ri,
        );
        return Ok((li, ri));
    }

    // Null-key rows never match; emit for outer joins.
    if want_left_unmatched {
        for &i in &lperm[..lstart] {
            li.push(i as i64);
            ri.push(-1);
        }
    }
    if want_right_unmatched {
        for &j in &rperm[..rstart] {
            li.push(-1);
            ri.push(j as i64);
        }
    }

    let mut a = lstart;
    let mut b = rstart;
    while a < lperm.len() && b < rperm.len() {
        let i = lperm[a];
        let j = rperm[b];
        match cmp_keys(&lk, i, &rk, j) {
            std::cmp::Ordering::Less => {
                if want_left_unmatched {
                    li.push(i as i64);
                    ri.push(-1);
                }
                a += 1;
            }
            std::cmp::Ordering::Greater => {
                if want_right_unmatched {
                    li.push(-1);
                    ri.push(j as i64);
                }
                b += 1;
            }
            std::cmp::Ordering::Equal => {
                // Extent of the equal run on each side.
                let a_end = run_end(&lperm, a, |x, y| {
                    cmp_keys(&lk, x, &lk, y) == std::cmp::Ordering::Equal
                });
                let b_end = run_end(&rperm, b, |x, y| {
                    cmp_keys(&rk, x, &rk, y) == std::cmp::Ordering::Equal
                });
                for &ii in &lperm[a..a_end] {
                    for &jj in &rperm[b..b_end] {
                        li.push(ii as i64);
                        ri.push(jj as i64);
                    }
                }
                a = a_end;
                b = b_end;
            }
        }
    }
    if want_left_unmatched {
        for &i in &lperm[a..] {
            li.push(i as i64);
            ri.push(-1);
        }
    }
    if want_right_unmatched {
        for &j in &rperm[b..] {
            li.push(-1);
            ri.push(j as i64);
        }
    }

    Ok((li, ri))
}

/// Monomorphic merge over pre-sorted i64 key permutations.
#[allow(clippy::too_many_arguments)]
fn merge_i64(
    lvals: &[i64],
    rvals: &[i64],
    lperm: &[usize],
    rperm: &[usize],
    want_left: bool,
    want_right: bool,
    li: &mut Vec<i64>,
    ri: &mut Vec<i64>,
) {
    let (mut a, mut b) = (0usize, 0usize);
    while a < lperm.len() && b < rperm.len() {
        let ka = lvals[lperm[a]];
        let kb = rvals[rperm[b]];
        if ka < kb {
            if want_left {
                li.push(lperm[a] as i64);
                ri.push(-1);
            }
            a += 1;
        } else if ka > kb {
            if want_right {
                li.push(-1);
                ri.push(rperm[b] as i64);
            }
            b += 1;
        } else {
            let mut a_end = a + 1;
            while a_end < lperm.len() && lvals[lperm[a_end]] == ka {
                a_end += 1;
            }
            let mut b_end = b + 1;
            while b_end < rperm.len() && rvals[rperm[b_end]] == kb {
                b_end += 1;
            }
            for &ii in &lperm[a..a_end] {
                for &jj in &rperm[b..b_end] {
                    li.push(ii as i64);
                    ri.push(jj as i64);
                }
            }
            a = a_end;
            b = b_end;
        }
    }
    if want_left {
        for &i in &lperm[a..] {
            li.push(i as i64);
            ri.push(-1);
        }
    }
    if want_right {
        for &j in &rperm[b..] {
            li.push(-1);
            ri.push(j as i64);
        }
    }
}

/// Argsort rows by key columns; single non-null-free i64 key uses the
/// radix path (the benchmark hot path).
fn argsort_keys(keys: &[&Column], nrows: usize) -> Vec<usize> {
    if keys.len() == 1 {
        if let Column::Int64(c) = keys[0] {
            return argsort_i64(c.values(), c.validity());
        }
    }
    argsort_by_columns(keys, &vec![false; keys.len()], nrows)
}

#[inline]
fn cmp_keys(
    a: &[&Column],
    i: usize,
    b: &[&Column],
    j: usize,
) -> std::cmp::Ordering {
    for (ca, cb) in a.iter().zip(b) {
        let o = ca.cmp_rows(i, cb, j);
        if o != std::cmp::Ordering::Equal {
            return o;
        }
    }
    std::cmp::Ordering::Equal
}

#[inline]
fn run_end<F: Fn(usize, usize) -> bool>(
    perm: &[usize],
    start: usize,
    eq: F,
) -> usize {
    let mut end = start + 1;
    while end < perm.len() && eq(perm[start], perm[end]) {
        end += 1;
    }
    end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::join::JoinAlgo;
    use crate::util::rng::Xoshiro256;

    /// Randomised differential test: sort join must agree with hash join
    /// on every join type (the crate's own cross-algorithm oracle).
    #[test]
    fn differential_vs_hash_join_randomised() {
        let mut r = Xoshiro256::new(1234);
        for trial in 0..20 {
            let nl = 1 + (r.next_below(60) as usize);
            let nr = 1 + (r.next_below(60) as usize);
            let domain = 1 + r.next_below(20) as i64;
            let lkeys: Vec<Option<i64>> = (0..nl)
                .map(|_| {
                    if r.next_below(10) == 0 {
                        None
                    } else {
                        Some(r.next_below(domain as u64) as i64)
                    }
                })
                .collect();
            let rkeys: Vec<Option<i64>> = (0..nr)
                .map(|_| {
                    if r.next_below(10) == 0 {
                        None
                    } else {
                        Some(r.next_below(domain as u64) as i64)
                    }
                })
                .collect();
            let l = Table::from_columns(vec![
                ("k", Column::from_opt_i64(lkeys)),
                (
                    "lv",
                    Column::from_i64((0..nl as i64).collect()),
                ),
            ])
            .unwrap();
            let rt = Table::from_columns(vec![
                ("k", Column::from_opt_i64(rkeys)),
                (
                    "rv",
                    Column::from_i64((0..nr as i64).collect()),
                ),
            ])
            .unwrap();
            for jt in [
                JoinType::Inner,
                JoinType::Left,
                JoinType::Right,
                JoinType::FullOuter,
            ] {
                let opts = JoinOptions::new(jt, &["k"], &["k"]);
                let (mut sl, mut sr) =
                    sort_join_indices(&l, &rt, &opts).unwrap();
                let (mut hl, mut hr) =
                    crate::ops::join::hash_join_indices(&l, &rt, &opts)
                        .unwrap();
                // Compare as multisets of (li, ri) pairs.
                let mut sp: Vec<(i64, i64)> =
                    sl.drain(..).zip(sr.drain(..)).collect();
                let mut hp: Vec<(i64, i64)> =
                    hl.drain(..).zip(hr.drain(..)).collect();
                sp.sort();
                hp.sort();
                assert_eq!(sp, hp, "trial={trial} jt={jt:?}");
            }
        }
    }

    #[test]
    fn string_keys_merge() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_str(&["b", "a", "c", "b"]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_str(&["b", "d"]),
        )])
        .unwrap();
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Sort);
        let (li, ri) = sort_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li.len(), 2);
        assert!(ri.iter().all(|&j| j == 0));
    }
}
