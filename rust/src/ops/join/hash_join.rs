//! Hash join: build a key→rows table on the right side, probe with the
//! left. Bucket hits re-verify actual key equality (hash collisions must
//! not fabricate matches).

use crate::compute::hash::{hash_columns, HashChains};
use crate::error::Result;
use crate::ops::join::{key_columns, key_has_null, JoinOptions, JoinType};
use crate::table::Table;

/// Compute matched row-index pairs (`-1` = null-extended side).
pub fn hash_join_indices(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
) -> Result<(Vec<i64>, Vec<i64>)> {
    let lk = key_columns(left, &opts.left_on)?;
    let rk = key_columns(right, &opts.right_on)?;

    // Hash both key sets.
    let mut lh = Vec::new();
    let mut rh = Vec::new();
    hash_columns(&lk, left.num_rows(), &mut lh);
    hash_columns(&rk, right.num_rows(), &mut rh);

    // Build side: right, as pre-hashed chains (§Perf: identity-hash map
    // + one chain allocation instead of HashMap<u64, Vec<u32>>).
    // Null-key rows are excluded (they match nothing) but tracked for
    // right/full outer output.
    let chains = HashChains::build(&rh, |j| key_has_null(&rk, j));

    let want_left_unmatched =
        matches!(opts.join_type, JoinType::Left | JoinType::FullOuter);
    let want_right_unmatched =
        matches!(opts.join_type, JoinType::Right | JoinType::FullOuter);

    let mut li: Vec<i64> = Vec::with_capacity(left.num_rows());
    let mut ri: Vec<i64> = Vec::with_capacity(left.num_rows());
    let mut right_matched = vec![false; right.num_rows()];

    // Monomorphic probe fast path for the common single-i64-key join.
    let fast = match (&lk[..], &rk[..]) {
        ([crate::column::Column::Int64(a)], [crate::column::Column::Int64(b)]) => {
            Some((a.values(), b.values()))
        }
        _ => None,
    };

    for (i, &h) in lh.iter().enumerate() {
        let mut matched = false;
        if !key_has_null(&lk, i) {
            match fast {
                Some((lvals, rvals)) => {
                    let key = lvals[i];
                    for j in chains.bucket(h) {
                        if rvals[j] == key {
                            li.push(i as i64);
                            ri.push(j as i64);
                            matched = true;
                            right_matched[j] = true;
                        }
                    }
                }
                None => {
                    for j in chains.bucket(h) {
                        // Collision-safe: verify every key cell.
                        let eq = lk
                            .iter()
                            .zip(&rk)
                            .all(|(a, b)| a.eq_rows(i, b, j));
                        if eq {
                            li.push(i as i64);
                            ri.push(j as i64);
                            matched = true;
                            right_matched[j] = true;
                        }
                    }
                }
            }
        }
        if !matched && want_left_unmatched {
            li.push(i as i64);
            ri.push(-1);
        }
    }

    if want_right_unmatched {
        for (j, &m) in right_matched.iter().enumerate() {
            if !m {
                li.push(-1);
                ri.push(j as i64);
            }
        }
    }

    Ok((li, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::join::JoinAlgo;

    #[test]
    fn collision_does_not_fabricate_match() {
        // Force a collision by joining on strings whose FNV hashes are
        // different — we can't easily force equal hashes, so instead
        // verify behaviour with equal hashes via identical values and
        // distinct values sharing a bucket modulo capacity: the
        // correctness property we rely on is the eq re-verification,
        // covered by joining values that differ only in payload.
        let l = Table::from_columns(vec![(
            "k",
            Column::from_str(&["aa", "bb"]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_str(&["bb", "cc"]),
        )])
        .unwrap();
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Hash);
        let (li, ri) = hash_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li, vec![1]);
        assert_eq!(ri, vec![0]);
    }

    #[test]
    fn inner_emits_only_matches() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![1, 2, 3]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![2, 4]),
        )])
        .unwrap();
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Hash);
        let (li, ri) = hash_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li, vec![1]);
        assert_eq!(ri, vec![0]);
    }

    #[test]
    fn full_outer_covers_both_sides() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![1, 2]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![2, 3]),
        )])
        .unwrap();
        let opts = JoinOptions::new(JoinType::FullOuter, &["k"], &["k"])
            .with_algo(JoinAlgo::Hash);
        let (li, ri) = hash_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li.len(), 3);
        // Exactly one pair with both sides set (k=2).
        let both = li
            .iter()
            .zip(&ri)
            .filter(|(&a, &b)| a >= 0 && b >= 0)
            .count();
        assert_eq!(both, 1);
    }
}
