//! Hash join: build a key→rows table on the right side, probe with the
//! left. Bucket hits re-verify actual key equality (hash collisions must
//! not fabricate matches).
//!
//! Both phases are morsel-parallel under the calling thread's intra-op
//! budget: the build radix-partitions rows by hash prefix so each
//! worker owns disjoint buckets ([`HashChains::build_parallel`]), and
//! the probe fans left-row morsels out with per-morsel output vectors
//! concatenated in morsel order — the emitted (left, right) index pairs
//! are bit-identical to the serial join at any thread count.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::column::Column;
use crate::compute::hash::{hash_columns, HashChains};
use crate::error::Result;
use crate::exec;
use crate::ops::join::{key_columns, key_has_null, JoinOptions, JoinType};
use crate::table::Table;

/// Compute matched row-index pairs (`-1` = null-extended side).
pub fn hash_join_indices(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
) -> Result<(Vec<i64>, Vec<i64>)> {
    let lk = key_columns(left, &opts.left_on)?;
    let rk = key_columns(right, &opts.right_on)?;

    // Hash both key sets (morsel-parallel inside hash_columns).
    let mut lh = Vec::new();
    let mut rh = Vec::new();
    hash_columns(&lk, left.num_rows(), &mut lh);
    hash_columns(&rk, right.num_rows(), &mut rh);

    // Build side: right, as pre-hashed chains (§Perf: identity-hash map
    // + one chain allocation instead of HashMap<u64, Vec<u32>>).
    // Null-key rows are excluded (they match nothing) but tracked for
    // right/full outer output.
    let build_exec = exec::parallelism_for(right.num_rows());
    let chains =
        HashChains::build_parallel(&rh, |j| key_has_null(&rk, j), build_exec);

    let want_left_unmatched =
        matches!(opts.join_type, JoinType::Left | JoinType::FullOuter);
    let want_right_unmatched =
        matches!(opts.join_type, JoinType::Right | JoinType::FullOuter);

    // Monomorphic probe fast path for the common single-i64-key join.
    let fast = match (&lk[..], &rk[..]) {
        ([Column::Int64(a)], [Column::Int64(b)]) => {
            Some((a.values(), b.values()))
        }
        _ => None,
    };

    let probe_exec = exec::parallelism_for(left.num_rows());
    let (mut li, mut ri, right_matched) = if probe_exec.is_parallel() {
        // Parallel probe: per-morsel pair vectors, morsel-order concat;
        // right-side match flags are monotonic so relaxed atomics keep
        // the exact serial flag set.
        let flags: Vec<AtomicBool> =
            (0..right.num_rows()).map(|_| AtomicBool::new(false)).collect();
        let parts = exec::for_each_morsel(left.num_rows(), probe_exec, |m| {
            let mut mli: Vec<i64> = Vec::new();
            let mut mri: Vec<i64> = Vec::new();
            probe_range(
                &lk,
                &rk,
                &lh,
                &chains,
                fast,
                m.start,
                m.end,
                want_left_unmatched,
                &mut mli,
                &mut mri,
                |j| flags[j].store(true, Ordering::Relaxed),
            );
            (mli, mri)
        });
        let total: usize = parts.iter().map(|(a, _)| a.len()).sum();
        let mut li = Vec::with_capacity(total);
        let mut ri = Vec::with_capacity(total);
        for (a, b) in parts {
            li.extend(a);
            ri.extend(b);
        }
        let matched: Vec<bool> =
            flags.iter().map(|f| f.load(Ordering::Relaxed)).collect();
        (li, ri, matched)
    } else {
        let mut li: Vec<i64> = Vec::with_capacity(left.num_rows());
        let mut ri: Vec<i64> = Vec::with_capacity(left.num_rows());
        let mut matched = vec![false; right.num_rows()];
        probe_range(
            &lk,
            &rk,
            &lh,
            &chains,
            fast,
            0,
            left.num_rows(),
            want_left_unmatched,
            &mut li,
            &mut ri,
            |j| matched[j] = true,
        );
        (li, ri, matched)
    };

    if want_right_unmatched {
        for (j, &m) in right_matched.iter().enumerate() {
            if !m {
                li.push(-1);
                ri.push(j as i64);
            }
        }
    }

    Ok((li, ri))
}

/// Probe left rows `[start, end)` against the right-side chains,
/// appending matches (and left-unmatched rows when requested) in left
/// row order. `mark(j)` records a right-side match.
#[allow(clippy::too_many_arguments)]
fn probe_range<FM: FnMut(usize)>(
    lk: &[&Column],
    rk: &[&Column],
    lh: &[u64],
    chains: &HashChains,
    fast: Option<(&[i64], &[i64])>,
    start: usize,
    end: usize,
    want_left_unmatched: bool,
    li: &mut Vec<i64>,
    ri: &mut Vec<i64>,
    mut mark: FM,
) {
    for i in start..end {
        let h = lh[i];
        let mut matched = false;
        if !key_has_null(lk, i) {
            match fast {
                Some((lvals, rvals)) => {
                    let key = lvals[i];
                    for j in chains.bucket(h) {
                        if rvals[j] == key {
                            li.push(i as i64);
                            ri.push(j as i64);
                            matched = true;
                            mark(j);
                        }
                    }
                }
                None => {
                    for j in chains.bucket(h) {
                        // Collision-safe: verify every key cell.
                        let eq = lk
                            .iter()
                            .zip(rk)
                            .all(|(a, b)| a.eq_rows(i, b, j));
                        if eq {
                            li.push(i as i64);
                            ri.push(j as i64);
                            matched = true;
                            mark(j);
                        }
                    }
                }
            }
        }
        if !matched && want_left_unmatched {
            li.push(i as i64);
            ri.push(-1);
        }
    }
}

/// Probe an explicit ascending list of left rows against pre-built
/// right-side chains — the fused-segment twin of [`probe_range`]: same
/// bucket walk, same collision re-verification, same emission order, so
/// the pairs emitted for `rows` are exactly the pairs [`probe_range`]
/// emits for those rows (with `li` holding the caller's global row
/// ids). `rh[k]` is the key hash of `rows[k]`
/// ([`crate::compute::hash::hash_rows`]); only Inner/Left probes fuse,
/// so no right-side match marking is needed here.
#[allow(clippy::too_many_arguments)]
pub(crate) fn probe_rows(
    lk: &[&Column],
    rk: &[&Column],
    rows: &[usize],
    rh: &[u64],
    chains: &HashChains,
    fast: Option<(&[i64], &[i64])>,
    want_left_unmatched: bool,
    li: &mut Vec<i64>,
    ri: &mut Vec<i64>,
) {
    for (k, &i) in rows.iter().enumerate() {
        let h = rh[k];
        let mut matched = false;
        if !key_has_null(lk, i) {
            match fast {
                Some((lvals, rvals)) => {
                    let key = lvals[i];
                    for j in chains.bucket(h) {
                        if rvals[j] == key {
                            li.push(i as i64);
                            ri.push(j as i64);
                            matched = true;
                        }
                    }
                }
                None => {
                    for j in chains.bucket(h) {
                        // Collision-safe: verify every key cell.
                        let eq = lk
                            .iter()
                            .zip(rk)
                            .all(|(a, b)| a.eq_rows(i, b, j));
                        if eq {
                            li.push(i as i64);
                            ri.push(j as i64);
                            matched = true;
                        }
                    }
                }
            }
        }
        if !matched && want_left_unmatched {
            li.push(i as i64);
            ri.push(-1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::join::JoinAlgo;

    #[test]
    fn collision_does_not_fabricate_match() {
        // Force a collision by joining on strings whose FNV hashes are
        // different — we can't easily force equal hashes, so instead
        // verify behaviour with equal hashes via identical values and
        // distinct values sharing a bucket modulo capacity: the
        // correctness property we rely on is the eq re-verification,
        // covered by joining values that differ only in payload.
        let l = Table::from_columns(vec![(
            "k",
            Column::from_str(&["aa", "bb"]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_str(&["bb", "cc"]),
        )])
        .unwrap();
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Hash);
        let (li, ri) = hash_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li, vec![1]);
        assert_eq!(ri, vec![0]);
    }

    #[test]
    fn inner_emits_only_matches() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![1, 2, 3]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![2, 4]),
        )])
        .unwrap();
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Hash);
        let (li, ri) = hash_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li, vec![1]);
        assert_eq!(ri, vec![0]);
    }

    #[test]
    fn full_outer_covers_both_sides() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![1, 2]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![2, 3]),
        )])
        .unwrap();
        let opts = JoinOptions::new(JoinType::FullOuter, &["k"], &["k"])
            .with_algo(JoinAlgo::Hash);
        let (li, ri) = hash_join_indices(&l, &r, &opts).unwrap();
        assert_eq!(li.len(), 3);
        // Exactly one pair with both sides set (k=2).
        let both = li
            .iter()
            .zip(&ri)
            .filter(|(&a, &b)| a >= 0 && b >= 0)
            .count();
        assert_eq!(both, 1);
    }

    #[test]
    fn parallel_probe_identical_index_pairs() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::new(404);
        let n = 20_000usize;
        let lkeys: Vec<Option<i64>> = (0..n)
            .map(|_| {
                if rng.next_below(11) == 0 {
                    None
                } else {
                    Some(rng.next_below(300) as i64)
                }
            })
            .collect();
        let rkeys: Vec<Option<i64>> = (0..n / 2)
            .map(|_| {
                if rng.next_below(11) == 0 {
                    None
                } else {
                    Some(rng.next_below(300) as i64)
                }
            })
            .collect();
        let l = Table::from_columns(vec![(
            "k",
            Column::from_opt_i64(lkeys),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_opt_i64(rkeys),
        )])
        .unwrap();
        for jt in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::FullOuter,
        ] {
            let opts = JoinOptions::new(jt, &["k"], &["k"])
                .with_algo(JoinAlgo::Hash);
            let serial = hash_join_indices(&l, &r, &opts).unwrap();
            let par = crate::exec::with_intra_op_threads(4, || {
                hash_join_indices(&l, &r, &opts).unwrap()
            });
            assert_eq!(par, serial, "{jt:?}");
        }
    }
}
