//! Join — "takes two tables and a set of join columns as input to produce
//! another table ... four types of joins: inner, left, right and full
//! outer" (Table I).
//!
//! Two algorithms, selectable via [`JoinAlgo`]:
//! * **Sort** (default — Cylon's core algorithm; the paper calls sorting
//!   "the core task in Cylon joins", §V-1): argsort both sides on the key
//!   columns, then merge equal-key runs emitting their cross products.
//! * **Hash**: build a hash table on the right side, probe with the left
//!   (collision-safe: bucket hits re-verify key equality cell-by-cell).
//!
//! Key semantics are SQL's: a row whose key contains a null matches
//! nothing (it still appears, null-extended, in the corresponding outer
//! joins).

mod grace;
mod hash_join;
mod sort_join;

use std::sync::Arc;

use crate::buffer::Bitmap;
use crate::column::{Column, PrimitiveColumn, StringColumn};
use crate::error::{Result, RylonError};
use crate::table::Table;

pub use hash_join::hash_join_indices;
pub(crate) use hash_join::probe_rows;
pub use sort_join::sort_join_indices;

/// Join semantics (Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinType {
    Inner,
    Left,
    Right,
    FullOuter,
}

impl JoinType {
    pub fn parse(s: &str) -> Option<JoinType> {
        match s {
            "inner" => Some(JoinType::Inner),
            "left" => Some(JoinType::Left),
            "right" => Some(JoinType::Right),
            "outer" | "full" | "full_outer" => Some(JoinType::FullOuter),
            _ => None,
        }
    }
}

/// Join algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinAlgo {
    Sort,
    Hash,
}

impl JoinAlgo {
    pub fn parse(s: &str) -> Option<JoinAlgo> {
        match s {
            "sort" => Some(JoinAlgo::Sort),
            "hash" => Some(JoinAlgo::Hash),
            _ => None,
        }
    }
}

/// Full specification of a join.
#[derive(Debug, Clone)]
pub struct JoinOptions {
    pub join_type: JoinType,
    pub algo: JoinAlgo,
    /// Key columns on the left table.
    pub left_on: Vec<String>,
    /// Key columns on the right table (same arity and dtypes).
    pub right_on: Vec<String>,
    /// Suffix applied to right-side columns that collide with left names.
    pub suffix: String,
}

impl JoinOptions {
    pub fn new(
        join_type: JoinType,
        left_on: &[&str],
        right_on: &[&str],
    ) -> JoinOptions {
        JoinOptions {
            join_type,
            algo: JoinAlgo::Sort,
            left_on: left_on.iter().map(|s| s.to_string()).collect(),
            right_on: right_on.iter().map(|s| s.to_string()).collect(),
            suffix: "_right".to_string(),
        }
    }

    /// Single-key inner join (the benchmark workload).
    pub fn inner(left_on: &str, right_on: &str) -> JoinOptions {
        JoinOptions::new(JoinType::Inner, &[left_on], &[right_on])
    }

    pub fn with_algo(mut self, algo: JoinAlgo) -> JoinOptions {
        self.algo = algo;
        self
    }

    pub fn with_suffix(mut self, suffix: &str) -> JoinOptions {
        self.suffix = suffix.to_string();
        self
    }
}

/// Resolved key columns for one side.
pub(crate) fn key_columns<'t>(
    table: &'t Table,
    names: &[String],
) -> Result<Vec<&'t Column>> {
    names.iter().map(|n| table.column_by_name(n)).collect()
}

/// Check key arity and dtype compatibility — shared by [`join`] and the
/// fused pipeline planner (`crate::pipeline::fuse`), so a fused join
/// fails with exactly the errors the materialized join raises.
pub(crate) fn validate(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
) -> Result<()> {
    if opts.left_on.is_empty() || opts.left_on.len() != opts.right_on.len() {
        return Err(RylonError::invalid(
            "join requires equal, non-empty key lists",
        ));
    }
    let lk = key_columns(left, &opts.left_on)?;
    let rk = key_columns(right, &opts.right_on)?;
    for (a, b) in lk.iter().zip(&rk) {
        if a.dtype() != b.dtype() {
            return Err(RylonError::ty(format!(
                "join key dtype mismatch: {} vs {}",
                a.dtype(),
                b.dtype()
            )));
        }
    }
    Ok(())
}

/// Execute a join and materialise the output table.
///
/// Hash joins consult the per-rank memory governor
/// ([`crate::exec::MemoryBudget`]): when the combined footprint of
/// both sides doesn't fit the budget, the join degrades to the grace
/// hash join — hash-partitioned RYF spill files joined one partition
/// at a time — with bit-identical output (`docs/MEMORY.md`).
pub fn join(left: &Table, right: &Table, opts: &JoinOptions) -> Result<Table> {
    validate(left, right, opts)?;
    let (li, ri) = match opts.algo {
        JoinAlgo::Hash => {
            let budget = crate::exec::MemoryBudget::current();
            match budget.try_reserve(left.byte_size() + right.byte_size()) {
                Some(_held) => hash_join_indices(left, right, opts)?,
                None => grace::grace_join_indices(
                    left, right, opts, &budget,
                )?,
            }
        }
        JoinAlgo::Sort => sort_join_indices(left, right, opts)?,
    };
    assemble(left, right, &li, &ri, &opts.suffix)
}

/// Build the output table from matched index pairs (`-1` = null side).
pub(crate) fn assemble(
    left: &Table,
    right: &Table,
    li: &[i64],
    ri: &[i64],
    suffix: &str,
) -> Result<Table> {
    debug_assert_eq!(li.len(), ri.len());
    let schema = left.schema().join(right.schema(), suffix);
    let mut cols: Vec<Arc<Column>> =
        Vec::with_capacity(left.num_columns() + right.num_columns());
    for c in left.columns() {
        cols.push(Arc::new(take_opt(c, li)));
    }
    for c in right.columns() {
        cols.push(Arc::new(take_opt(c, ri)));
    }
    Ok(Table::from_parts(schema, cols, li.len()))
}

/// Gather with `-1` → null. Falls back to the dense `take` when no
/// sentinel is present (inner joins stay on the fast path, morsel-
/// parallel for dense fixed-width columns).
pub(crate) fn take_opt(col: &Column, idx: &[i64]) -> Column {
    if idx.iter().all(|&i| i >= 0) {
        let dense: Vec<usize> = idx.iter().map(|&i| i as usize).collect();
        return crate::compute::filter::take_column_parallel(
            col,
            &dense,
            crate::exec::parallelism_for(dense.len()),
        );
    }
    match col {
        Column::Int64(c) => Column::Int64(take_opt_prim(c, idx)),
        Column::Float64(c) => Column::Float64(take_opt_prim(c, idx)),
        Column::Bool(c) => Column::Bool(take_opt_prim(c, idx)),
        Column::Utf8(c) => Column::Utf8(take_opt_str(c, idx)),
    }
}

/// Serial `-1`-aware gather for one primitive column — also the
/// per-morsel gather of the fused pipeline (`crate::pipeline::fuse`),
/// which must not nest parallel kernels inside a morsel closure.
pub(crate) fn take_opt_prim<T: Copy + Default>(
    c: &PrimitiveColumn<T>,
    idx: &[i64],
) -> PrimitiveColumn<T> {
    let mut values = Vec::with_capacity(idx.len());
    let mut validity = Bitmap::zeros(idx.len());
    for (out_i, &i) in idx.iter().enumerate() {
        if i >= 0 && c.is_valid(i as usize) {
            values.push(c.value(i as usize));
            validity.set(out_i, true);
        } else {
            values.push(T::default());
        }
    }
    PrimitiveColumn::from_options(
        values
            .into_iter()
            .enumerate()
            .map(|(i, v)| if validity.get(i) { Some(v) } else { None })
            .collect(),
    )
}

/// Serial `-1`-aware gather for one string column (see
/// [`take_opt_prim`] on fused-pipeline use).
pub(crate) fn take_opt_str(c: &StringColumn, idx: &[i64]) -> StringColumn {
    let vals: Vec<Option<&str>> = idx
        .iter()
        .map(|&i| {
            if i >= 0 {
                c.get(i as usize)
            } else {
                None
            }
        })
        .collect();
    StringColumn::from_options(&vals)
}

/// True if any key cell of row `row` is null (such rows match nothing).
#[inline]
pub(crate) fn key_has_null(keys: &[&Column], row: usize) -> bool {
    keys.iter().any(|c| !c.is_valid(row))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn left() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_opt_i64(vec![Some(1), Some(2), Some(2), None])),
            ("lv", Column::from_str(&["a", "b", "c", "d"])),
        ])
        .unwrap()
    }

    fn right() -> Table {
        Table::from_columns(vec![
            ("id", Column::from_opt_i64(vec![Some(2), Some(3), None])),
            ("rv", Column::from_f64(vec![20.0, 30.0, 99.0])),
        ])
        .unwrap()
    }

    fn sorted_rows(t: &Table) -> Vec<Vec<crate::types::Value>> {
        let mut rows: Vec<_> = (0..t.num_rows()).map(|i| t.row(i)).collect();
        rows.sort_by(|a, b| {
            for (x, y) in a.iter().zip(b) {
                let o = x.total_cmp(y);
                if o != std::cmp::Ordering::Equal {
                    return o;
                }
            }
            std::cmp::Ordering::Equal
        });
        rows
    }

    fn check_both_algos(jt: JoinType, expect_rows: usize) {
        let opts = JoinOptions::new(jt, &["id"], &["id"]);
        let hash = join(&left(), &right(), &opts.clone().with_algo(JoinAlgo::Hash))
            .unwrap();
        let sort = join(&left(), &right(), &opts.with_algo(JoinAlgo::Sort))
            .unwrap();
        assert_eq!(hash.num_rows(), expect_rows, "{jt:?} hash");
        assert_eq!(sort.num_rows(), expect_rows, "{jt:?} sort");
        // Same multiset of rows regardless of algorithm.
        assert_eq!(sorted_rows(&hash), sorted_rows(&sort), "{jt:?}");
    }

    #[test]
    fn inner_join_counts() {
        // id=2 matches twice on the left × once on the right = 2 rows.
        // Null keys match nothing.
        check_both_algos(JoinType::Inner, 2);
    }

    #[test]
    fn left_join_counts() {
        // 2 matches + unmatched left rows {1, null} = 4.
        check_both_algos(JoinType::Left, 4);
    }

    #[test]
    fn right_join_counts() {
        // 2 matches + unmatched right rows {3, null} = 4.
        check_both_algos(JoinType::Right, 4);
    }

    #[test]
    fn full_outer_counts() {
        // 2 matches + left-unmatched {1, null} + right-unmatched {3, null}.
        check_both_algos(JoinType::FullOuter, 6);
    }

    #[test]
    fn output_schema_suffix() {
        let j = join(
            &left(),
            &right(),
            &JoinOptions::inner("id", "id"),
        )
        .unwrap();
        let names: Vec<_> = j
            .schema()
            .fields()
            .iter()
            .map(|f| f.name.as_str())
            .collect();
        assert_eq!(names, vec!["id", "lv", "id_right", "rv"]);
    }

    #[test]
    fn left_join_null_extension() {
        let j = join(
            &left(),
            &right(),
            &JoinOptions::new(JoinType::Left, &["id"], &["id"]),
        )
        .unwrap();
        // Find the row with lv == "a" (left id=1, unmatched).
        let lv = j.column_by_name("lv").unwrap();
        let rv = j.column_by_name("rv").unwrap();
        let row = (0..j.num_rows())
            .find(|&i| lv.value(i) == crate::types::Value::Utf8("a".into()))
            .unwrap();
        assert!(rv.value(row).is_null());
    }

    #[test]
    fn validation_errors() {
        let opts = JoinOptions::new(JoinType::Inner, &[], &[]);
        assert!(join(&left(), &right(), &opts).is_err());
        let opts = JoinOptions::new(JoinType::Inner, &["id"], &["rv"]);
        assert!(join(&left(), &right(), &opts).is_err()); // dtype mismatch
        let opts = JoinOptions::new(JoinType::Inner, &["ghost"], &["id"]);
        assert!(join(&left(), &right(), &opts).is_err());
    }

    #[test]
    fn multi_key_join() {
        let l = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2])),
            ("b", Column::from_str(&["x", "y", "x"])),
            ("v", Column::from_i64(vec![10, 11, 12])),
        ])
        .unwrap();
        let r = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 2])),
            ("b", Column::from_str(&["y", "x"])),
            ("w", Column::from_i64(vec![100, 200])),
        ])
        .unwrap();
        for algo in [JoinAlgo::Hash, JoinAlgo::Sort] {
            let j = join(
                &l,
                &r,
                &JoinOptions::new(JoinType::Inner, &["a", "b"], &["a", "b"])
                    .with_algo(algo),
            )
            .unwrap();
            assert_eq!(j.num_rows(), 2, "{algo:?}");
            let mut vs: Vec<i64> =
                j.column_by_name("v").unwrap().i64_values().to_vec();
            vs.sort();
            assert_eq!(vs, vec![11, 12]);
        }
    }

    #[test]
    fn empty_inputs() {
        let e = Table::empty(left().schema().clone());
        for algo in [JoinAlgo::Hash, JoinAlgo::Sort] {
            let opts = JoinOptions::inner("id", "id").with_algo(algo);
            assert_eq!(join(&e, &right(), &opts).unwrap().num_rows(), 0);
            assert_eq!(join(&left(), &e, &opts).unwrap().num_rows(), 0);
            let lo = JoinOptions::new(JoinType::Left, &["id"], &["id"])
                .with_algo(algo);
            assert_eq!(
                join(&left(), &e, &lo).unwrap().num_rows(),
                left().num_rows()
            );
        }
    }

    #[test]
    fn duplicate_heavy_cross_product() {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![7, 7, 7]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![7, 7]),
        )])
        .unwrap();
        for algo in [JoinAlgo::Hash, JoinAlgo::Sort] {
            let j = join(
                &l,
                &r,
                &JoinOptions::inner("k", "k").with_algo(algo),
            )
            .unwrap();
            assert_eq!(j.num_rows(), 6, "{algo:?}");
        }
    }
}
