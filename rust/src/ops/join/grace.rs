//! Grace hash join — the out-of-core fallback [`super::join`] takes
//! when the memory governor denies the in-memory hash join's working
//! set (`docs/MEMORY.md`).
//!
//! Both sides are routed by the combined key hash through the same
//! [`HashPartitioner`] the distributed shuffle uses, gathered one
//! partition at a time on the worker pool, and spilled as RYF row
//! groups under a per-episode [`SpillDir`] (dropped — and therefore
//! deleted — on success *and* when an abort unwinds through this
//! frame). Equal keys share a hash, so every match is partition-local;
//! each partition pair is then read back and joined in memory if its
//! working set now fits, or recursively re-partitioned (with a coprime
//! partition count, so the modulus actually re-splits) if it does not.
//!
//! The emitted index pairs are **bit-identical** to
//! [`hash_join_indices`] on the whole input: the serial hash join
//! emits left rows in ascending order (each row's matches in bucket
//! order), then right-unmatched rows ascending. A left row lives in
//! exactly one partition, so its matches arrive contiguously and in
//! the same bucket order from that partition's in-memory join; a
//! stable sort of the left-anchored pairs by left row id and an
//! ascending sort of the right-unmatched ids restore the global order
//! exactly. The equivalence matrix in
//! `rust/tests/intra_op_equivalence.rs` pins this at every thread
//! count.

use crate::compute::filter::{scatter_indices, take_parallel};
use crate::dist::{HashPartitioner, Partitioner};
use crate::error::Result;
use crate::exec::{self, MemoryBudget, SpillDir};
use crate::io::ryf::{read_ryf_footer, read_ryf_group, RyfWriter};
use crate::ops::join::hash_join::hash_join_indices;
use crate::ops::join::JoinOptions;
use crate::table::Table;

/// Partition counts per recursion level. Pairwise coprime, so a
/// partition formed at level *d* (rows with `hash % PARTS[d] == p`)
/// still splits `PARTS[d+1]` ways at the next level — reusing the
/// unsalted [`HashPartitioner`] hash at every depth.
const GRACE_PARTS: [usize; 4] = [8, 11, 13, 17];

/// Recursion ceiling: past this depth an unsplittable partition (e.g.
/// every row sharing one key) is joined in memory regardless of the
/// budget — the governor is an admission target, not a hard allocator.
const MAX_GRACE_DEPTH: usize = GRACE_PARTS.len() - 1;

/// Out-of-core twin of [`hash_join_indices`]: identical output pairs,
/// O(partition) resident memory instead of O(input).
pub(crate) fn grace_join_indices(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    budget: &MemoryBudget,
) -> Result<(Vec<i64>, Vec<i64>)> {
    grace_level(left, right, opts, budget, 0)
}

fn grace_level(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    budget: &MemoryBudget,
    depth: usize,
) -> Result<(Vec<i64>, Vec<i64>)> {
    let nparts = GRACE_PARTS[depth.min(MAX_GRACE_DEPTH)];
    let mut lp = Vec::new();
    let mut rp = Vec::new();
    HashPartitioner::new(&opts.left_on, nparts)?.partition(left, &mut lp)?;
    HashPartitioner::new(&opts.right_on, nparts)?.partition(right, &mut rp)?;
    let lrows = scatter_indices(&lp, nparts);
    let rrows = scatter_indices(&rp, nparts);
    drop((lp, rp));

    // Spill phase: gather each partition (worker-pool gather kernels)
    // and write it out as one RYF row group, holding only a single
    // partition's sub-table at a time. The directory is removed when
    // `dir` drops — normal return or unwind alike.
    let dir = SpillDir::create()?;
    let lpath = dir.file("join-left.ryf");
    let rpath = dir.file("join-right.ryf");
    for (path, table, rows) in
        [(&lpath, left, &lrows), (&rpath, right, &rrows)]
    {
        let mut w = RyfWriter::create(path)?;
        for part_rows in rows.iter() {
            let part = take_parallel(
                table,
                part_rows,
                exec::parallelism_for(part_rows.len()),
            );
            exec::note_spill(part.byte_size() as u64);
            w.append(&part)?;
        }
        w.finish()?;
    }

    // Probe phase: read partition pairs back one at a time; join in
    // memory when the governor now admits the pair, recurse when it
    // does not (and the partition actually shrank).
    let lmetas = read_ryf_footer(&lpath)?;
    let rmetas = read_ryf_footer(&rpath)?;
    let mut pairs: Vec<(i64, i64)> = Vec::new();
    let mut right_unmatched: Vec<i64> = Vec::new();
    for p in 0..nparts {
        let lsub = read_ryf_group(&lpath, &lmetas[p])?;
        let rsub = read_ryf_group(&rpath, &rmetas[p])?;
        if lsub.num_rows() == 0 && rsub.num_rows() == 0 {
            continue;
        }
        let splittable = depth < MAX_GRACE_DEPTH
            && (lsub.num_rows() < left.num_rows()
                || rsub.num_rows() < right.num_rows());
        let need = lsub.byte_size() + rsub.byte_size();
        let (li, ri) = match budget.try_reserve(need) {
            Some(_held) => hash_join_indices(&lsub, &rsub, opts)?,
            None if splittable => {
                grace_level(&lsub, &rsub, opts, budget, depth + 1)?
            }
            None => hash_join_indices(&lsub, &rsub, opts)?,
        };
        for (&a, &b) in li.iter().zip(&ri) {
            let gr = if b >= 0 { rrows[p][b as usize] as i64 } else { -1 };
            if a >= 0 {
                pairs.push((lrows[p][a as usize] as i64, gr));
            } else {
                right_unmatched.push(gr);
            }
        }
    }

    // Restore the serial emission order (module docs): stable by left
    // row id, then right-unmatched ascending.
    pairs.sort_by_key(|&(l, _)| l);
    right_unmatched.sort_unstable();
    let mut li = Vec::with_capacity(pairs.len() + right_unmatched.len());
    let mut ri = Vec::with_capacity(pairs.len() + right_unmatched.len());
    for (a, b) in pairs {
        li.push(a);
        ri.push(b);
    }
    for b in right_unmatched {
        li.push(-1);
        ri.push(b);
    }
    Ok((li, ri))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::join::{JoinAlgo, JoinType};
    use crate::util::rng::Xoshiro256;

    fn random_pair(seed: u64, n: usize) -> (Table, Table) {
        let mut rng = Xoshiro256::new(seed);
        let opt_keys = |rng: &mut Xoshiro256, n: usize| -> Vec<Option<i64>> {
            (0..n)
                .map(|_| {
                    if rng.next_below(13) == 0 {
                        None
                    } else {
                        Some(rng.next_below(40) as i64)
                    }
                })
                .collect()
        };
        let lk = opt_keys(&mut rng, n);
        let rk = opt_keys(&mut rng, n / 2 + 1);
        let lv: Vec<i64> = (0..n as i64).collect();
        let rv: Vec<f64> = (0..n / 2 + 1).map(|i| i as f64 * 0.5).collect();
        (
            Table::from_columns(vec![
                ("k", Column::from_opt_i64(lk)),
                ("lv", Column::from_i64(lv)),
            ])
            .unwrap(),
            Table::from_columns(vec![
                ("k", Column::from_opt_i64(rk)),
                ("rv", Column::from_f64(rv)),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn grace_pairs_bit_identical_to_in_memory_all_join_types() {
        let (l, r) = random_pair(777, 600);
        for jt in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::FullOuter,
        ] {
            let opts = JoinOptions::new(jt, &["k"], &["k"])
                .with_algo(JoinAlgo::Hash);
            let oracle = hash_join_indices(&l, &r, &opts).unwrap();
            // A 1-byte budget denies every per-partition reservation,
            // forcing recursion to the depth cap.
            let tiny = MemoryBudget::with_limit(1);
            let grace = grace_join_indices(&l, &r, &opts, &tiny).unwrap();
            assert_eq!(grace, oracle, "{jt:?} (recursive)");
            // A budget that admits each partition but not the whole
            // input exercises the single-level path.
            let mid = MemoryBudget::with_limit(
                l.byte_size() + r.byte_size() - 1,
            );
            let one = grace_join_indices(&l, &r, &opts, &mid).unwrap();
            assert_eq!(one, oracle, "{jt:?} (one level)");
        }
    }

    #[test]
    fn grace_cleans_up_spill_dirs() {
        let before = exec::live_spill_dirs();
        let (l, r) = random_pair(42, 200);
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Hash);
        let tiny = MemoryBudget::with_limit(1);
        grace_join_indices(&l, &r, &opts, &tiny).unwrap();
        assert_eq!(exec::live_spill_dirs(), before);
    }

    #[test]
    fn unsplittable_partition_falls_back_in_memory() {
        // Every key equal: no partitioning can split the build side,
        // so the depth cap must end the recursion, not a stack
        // overflow.
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![7; 64]),
        )])
        .unwrap();
        let r = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![7; 32]),
        )])
        .unwrap();
        let opts = JoinOptions::inner("k", "k").with_algo(JoinAlgo::Hash);
        let oracle = hash_join_indices(&l, &r, &opts).unwrap();
        let tiny = MemoryBudget::with_limit(1);
        let grace = grace_join_indices(&l, &r, &opts, &tiny).unwrap();
        assert_eq!(grace, oracle);
        assert_eq!(grace.0.len(), 64 * 32);
    }
}
