//! Local relational-algebra operators — the paper's Table I: select,
//! project, join (inner/left/right/full-outer × hash/sort), union,
//! intersect, difference — plus the DataTable API extras PyCylon exposes
//! (groupby, orderby).
//!
//! Every operator here is *local* (one partition); the distributed
//! versions in [`crate::dist`] compose these with a key-based shuffle
//! exactly as the paper describes (§III-C: "a key-based partition
//! followed by a key-based shuffle ... to collect similar records into a
//! single process").

pub mod select;
pub mod project;
pub mod join;
pub mod set_ops;
pub mod groupby;
pub mod orderby;

pub use groupby::{groupby, Agg, GroupByOptions};
pub use join::{join, JoinAlgo, JoinOptions, JoinType};
pub use orderby::{orderby, SortKey, SortOrder};
pub use project::project;
pub use select::{select, Predicate};
pub use set_ops::{difference, distinct, intersect, subtract, union};
