//! `rylon` — the launcher/CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   gen      generate a synthetic CSV workload
//!   inspect  read a CSV, print schema + head
//!   join     distributed join of two CSVs (threads or sim fabric)
//!   etl      run the demo ETL pipeline end-to-end
//!   bench    regenerate a paper figure (--fig fig10|fig11|fig12|ablations)
//!   convert  streaming bounded-memory CSV → RYF conversion
//!
//! `--config path.toml` loads a [`rylon::conf::RylonConfig`]; flags
//! override config values. Run `rylon help` for flag details.

use std::collections::HashMap;

use rylon::bench_harness::{figures, BenchOpts};
use rylon::conf::RylonConfig;
use rylon::dist::{Cluster, DistConfig, FabricKind};
use rylon::error::{Result, RylonError};
use rylon::io::csv::{read_csv, write_csv, CsvOptions};
use rylon::io::datagen::{gen_table, DataGenSpec, KeyDist};
use rylon::net::tcp::TcpOpts;
use rylon::ops::groupby::{Agg, GroupByOptions};
use rylon::ops::join::JoinOptions;
use rylon::pipeline::{Env, Pipeline};
use rylon::runtime::Runtime;
use rylon::util::fmt::{human_bytes, human_count};

const HELP: &str = "\
rylon — HPC data engineering with a distributed table abstraction
(reproduction of 'Data Engineering for HPC with Python', CS.DC 2020)

USAGE: rylon <command> [flags]

COMMANDS
  gen      --rows N [--payload-cols K] [--dist uniform|zipf|seq]
           [--seed S] --out FILE.csv
  inspect  --in FILE.csv [--rows N]
  join     --left L.csv --right R.csv --on KEY [--how inner|left|right|outer]
           [--algo sort|hash] [--world P] [--fabric threads|sim|tcp]
           [--out F.csv]
  etl      [--rows N] [--world P] [--fabric threads|sim|tcp]
           [--in FILE.ryf] [--artifacts DIR]
           (end-to-end demo pipeline + tensor bridge; with --in the
           fact table is scanned from an RYF file with predicate and
           projection pushdown — zone-map skips and decoded-bytes
           counters land in the phase JSON)
  bench    --fig fig10|fig11|fig12|ablations [--rows N] [--samples K]
           [--max-world P] [--artifacts DIR]
  bench run-all
           [--recipes DIR] [--out DIR] [--recipe NAME] [--samples K]
           (run every YAML bench recipe in --recipes, default
           bench/recipes, and write one summary JSON per recipe to
           --out, default bench/results; each run cross-checks the
           encoded scan against the raw-format oracle and fails on
           any bit-identity violation)
  sql      --query 'SELECT …' --tables name=a.csv,name2=b.csv
           [--out FILE.csv]
  convert  --in FILE.csv --out FILE.ryf [--group-rows N]
           --in FILE.ryf --out FILE.csv   (direction from --in suffix;
           streaming, bounded-memory both ways)
  help

GLOBAL FLAGS
  --config FILE.toml    load defaults from a config file
  --fabric KIND         communication substrate for cluster commands:
                        threads (rank threads, default), sim (BSP cost
                        model), tcp (one OS process per rank over
                        loopback/LAN sockets — docs/NET.md)
  --rendezvous ADDR     host:port where tcp ranks meet (default
                        127.0.0.1:29400; rank 0 listens, peers dial)
  --rank R              join an already-launched tcp job as rank R;
                        without it, join/etl under --fabric tcp
                        self-launch all world rank processes and wait
  --intra-threads N     morsel workers per rank for local kernels
                        (0 = auto: cores/world; 1 = serial ranks)
  --par-threshold N     rows below which kernels stay serial
                        (default 4096; lower it to force the parallel
                        paths on small inputs)
  --ingest-chunk BYTES  streaming CSV ingest chunk size (0 = default
                        4 MiB; raw-text memory during ingest is
                        O(chunk), not O(file))
  --ingest-single-pass true|false
                        distributed CSV ingest scheme (default true:
                        byte-range speculation, each byte read once
                        per cluster; false = two-pass count+parse)
  --work-steal true|false
                        cross-rank work stealing (default true: idle
                        rank workers run a skewed rank's queued
                        morsels; false = isolated per-rank pools;
                        results identical either way)
  --pipeline-fuse true|false
                        fused pipeline execution (default true:
                        select/project/join-probe/partial-agg run as
                        one pass per morsel with no intermediate
                        table; false = operator-at-a-time with a full
                        table between stages; results identical
                        either way — docs/PIPELINE.md)
  --ryf-encoding true|false
                        RYF write format (default true: encoded RYF2
                        row groups — dictionary/RLE/bit-packed columns
                        with zone-map statistics that let scans skip
                        whole groups; false = raw RYF1, the
                        bit-identity oracle; readers accept both —
                        docs/STORAGE.md)
  --fault-plan PLAN     deterministic fault injection for cluster
                        commands: comma-separated kind@rank:exchange
                        entries, kind = error|panic|exit|delayMS (e.g.
                        'error@1:2'; exit kills the whole rank process
                        — tcp fabric only); empty = off (docs/FAULTS.md)
  --collective-timeout MS
                        abort any collective not completing within MS
                        milliseconds, blaming the missing rank
                        (0 = wait forever, the default)
  --memory-budget BYTES
                        per-rank memory budget for join/sort/groupby
                        (0 = unbounded, the default: all-in-memory
                        paths). Operators whose working set exceeds
                        the budget spill RYF partitions to a temp dir
                        and stream them back; results are identical
                        either way — docs/MEMORY.md

See docs/CONFIG.md for the config-file/env equivalents of every knob.
";

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut flags = HashMap::new();
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| {
                    RylonError::invalid(format!(
                        "expected --flag, got '{}'",
                        argv[i]
                    ))
                })?
                .to_string();
            let v = argv.get(i + 1).cloned().ok_or_else(|| {
                RylonError::invalid(format!("flag --{k} needs a value"))
            })?;
            flags.insert(k, v);
            i += 2;
        }
        Ok(Args { cmd, flags })
    }

    fn str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn req(&self, key: &str) -> Result<&str> {
        self.str(key).ok_or_else(|| {
            RylonError::invalid(format!("missing required flag --{key}"))
        })
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Tri-state boolean flag: absent = `None` (defer to config/env).
    fn bool_flag(&self, key: &str) -> Result<Option<bool>> {
        match self.str(key) {
            None => Ok(None),
            Some("1") | Some("true") => Ok(Some(true)),
            Some("0") | Some("false") => Ok(Some(false)),
            Some(other) => Err(RylonError::invalid(format!(
                "flag --{key} wants true|false, got '{other}'"
            ))),
        }
    }
}

fn load_config(args: &Args) -> Result<RylonConfig> {
    match args.str("config") {
        Some(path) => RylonConfig::load(path),
        None => Ok(RylonConfig::default()),
    }
}

fn make_cluster(
    args: &Args,
    cfg: &RylonConfig,
    world: usize,
) -> Result<Cluster> {
    let fabric = args.str("fabric").unwrap_or(&cfg.fabric).to_string();
    let kind = match fabric.as_str() {
        "threads" => FabricKind::Threads,
        "sim" => FabricKind::Sim(cfg.cost),
        "tcp" => {
            let rank = match args.str("rank") {
                Some(v) => v.parse::<usize>().map_err(|_| {
                    RylonError::invalid(format!(
                        "flag --rank wants a rank number, got '{v}'"
                    ))
                })?,
                None => {
                    return Err(RylonError::invalid(
                        "tcp fabric needs --rank R (join/etl launch \
                         rank processes automatically when --rank is \
                         omitted)",
                    ))
                }
            };
            let rendezvous = args
                .str("rendezvous")
                .unwrap_or(&cfg.rendezvous)
                .to_string();
            FabricKind::Tcp(TcpOpts::new(rank, rendezvous))
        }
        other => {
            return Err(RylonError::invalid(format!(
                "unknown fabric '{other}' (threads|sim|tcp)"
            )))
        }
    };
    Cluster::new(DistConfig {
        world,
        fabric: kind,
        shuffle_chunk_rows: cfg.shuffle_chunk_rows,
        intra_op_threads: args
            .usize_or("intra-threads", cfg.intra_op_threads),
        par_row_threshold: args
            .usize_or("par-threshold", cfg.par_row_threshold),
        ingest_chunk_bytes: args
            .usize_or("ingest-chunk", cfg.ingest_chunk_bytes),
        ingest_single_pass: args
            .bool_flag("ingest-single-pass")?
            .or(cfg.ingest_single_pass),
        work_steal: args.bool_flag("work-steal")?.or(cfg.work_steal),
        pipeline_fuse: args
            .bool_flag("pipeline-fuse")?
            .or(cfg.pipeline_fuse),
        ryf_encoding: args
            .bool_flag("ryf-encoding")?
            .or(cfg.ryf_encoding),
        fault_plan: args
            .str("fault-plan")
            .map(String::from)
            .or_else(|| cfg.fault_plan.clone()),
        collective_timeout_ms: match args.str("collective-timeout") {
            Some(v) => Some(v.parse().map_err(|_| {
                RylonError::invalid(format!(
                    "flag --collective-timeout wants milliseconds, \
                     got '{v}'"
                ))
            })?),
            None => cfg.collective_timeout_ms,
        },
        memory_budget_bytes: args
            .usize_or("memory-budget", cfg.memory_budget_bytes),
    })
}

/// Whether this invocation should act as the TCP *launcher*: the user
/// picked the tcp fabric for a cluster command but gave no `--rank`,
/// so this process spawns all `world` rank processes (each re-running
/// the same command line plus `--rank R`) and waits for them.
fn tcp_launcher_selected(args: &Args, cfg: &RylonConfig) -> bool {
    args.str("fabric").unwrap_or(&cfg.fabric) == "tcp"
        && args.str("rank").is_none()
}

/// Spawn one rank process per rank of a TCP job and wait for all of
/// them, reporting every rank that exited with failure. The children
/// re-run this binary with the original command line plus explicit
/// `--fabric tcp --world W --rendezvous ADDR --rank R` (the flag
/// parser is last-wins, so replaying the original argv first is safe).
fn launch_tcp_ranks(
    argv: &[String],
    args: &Args,
    cfg: &RylonConfig,
) -> Result<()> {
    let world = args.usize_or("world", cfg.world);
    let rendezvous = args
        .str("rendezvous")
        .unwrap_or(&cfg.rendezvous)
        .to_string();
    let exe = std::env::current_exe().map_err(|e| {
        RylonError::invalid(format!(
            "tcp launch: cannot locate this executable: {e}"
        ))
    })?;
    println!(
        "== rylon tcp launch: {world} rank processes, rendezvous \
         {rendezvous} =="
    );
    let mut children = Vec::with_capacity(world);
    for rank in 0..world {
        let child = std::process::Command::new(&exe)
            .args(argv)
            .arg("--fabric")
            .arg("tcp")
            .arg("--world")
            .arg(world.to_string())
            .arg("--rendezvous")
            .arg(&rendezvous)
            .arg("--rank")
            .arg(rank.to_string())
            .spawn()
            .map_err(|e| {
                // Reap what already launched; their handshake will
                // fail without the missing sibling anyway.
                RylonError::invalid(format!(
                    "tcp launch: cannot spawn rank {rank}: {e}"
                ))
            })?;
        children.push((rank, child));
    }
    let mut failed: Vec<usize> = Vec::new();
    for (rank, mut child) in children {
        let ok = child.wait().map(|s| s.success()).unwrap_or(false);
        if !ok {
            failed.push(rank);
        }
    }
    if failed.is_empty() {
        println!("== all {world} ranks completed ==");
        Ok(())
    } else {
        Err(RylonError::comm(format!(
            "tcp launch: rank(s) {failed:?} exited with failure (see \
             their stderr above)"
        )))
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    let rows = args.usize_or("rows", 100_000);
    let payload = args.usize_or("payload-cols", 3);
    let seed = args.usize_or("seed", 42) as u64;
    let out = args.req("out")?;
    let key_dist = match args.str("dist").unwrap_or("uniform") {
        "uniform" => KeyDist::Uniform {
            domain: (rows as u64 * 2).max(1),
        },
        "zipf" => KeyDist::Zipf {
            domain: (rows as u64 * 2).max(1),
            s: 1.1,
        },
        "seq" => KeyDist::Sequential,
        other => {
            return Err(RylonError::invalid(format!(
                "unknown key dist '{other}'"
            )))
        }
    };
    let t = gen_table(&DataGenSpec {
        rows,
        payload_cols: payload,
        key_dist,
        seed,
    })?;
    write_csv(&t, out, &CsvOptions::default())?;
    println!(
        "wrote {} rows × {} cols ({}) to {out}",
        human_count(t.num_rows() as u64),
        t.num_columns(),
        human_bytes(t.byte_size() as u64),
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.req("in")?;
    let t = read_csv(path, &CsvOptions::default())?;
    println!("schema: {}", t.schema());
    println!(
        "rows: {}   bytes: {}",
        human_count(t.num_rows() as u64),
        human_bytes(t.byte_size() as u64)
    );
    println!("{}", t.pretty(args.usize_or("rows", 10)));
    Ok(())
}

fn cmd_join(args: &Args, cfg: &RylonConfig) -> Result<()> {
    let left = read_csv(args.req("left")?, &CsvOptions::default())?;
    let right = read_csv(args.req("right")?, &CsvOptions::default())?;
    let on = args.req("on")?;
    let how = args.str("how").unwrap_or("inner");
    let jt = rylon::ops::join::JoinType::parse(how)
        .ok_or_else(|| RylonError::invalid(format!("bad --how {how}")))?;
    let algo = rylon::ops::join::JoinAlgo::parse(
        args.str("algo").unwrap_or("sort"),
    )
    .ok_or_else(|| RylonError::invalid("bad --algo"))?;
    let opts = JoinOptions::new(jt, &[on], &[on]).with_algo(algo);
    let world = args.usize_or("world", cfg.world);

    let timer = rylon::metrics::Timer::start();
    let cluster = make_cluster(args, cfg, world)?;
    let outs = cluster.run(|ctx| {
        // Block-partition the inputs across ranks.
        let slice = |t: &rylon::table::Table| {
            let n = t.num_rows();
            let base = n / ctx.size;
            let extra = n % ctx.size;
            let my = base + (ctx.rank < extra) as usize;
            let off = base * ctx.rank + ctx.rank.min(extra);
            t.slice(off, my)
        };
        rylon::dist::dist_join(ctx, &slice(&left), &slice(&right), &opts)
    })?;
    let total: usize = outs.iter().map(|t| t.num_rows()).sum();
    println!(
        "join produced {} rows across {world} ranks in {:.3}s{}",
        human_count(total as u64),
        timer.seconds(),
        cluster
            .makespan()
            .map(|m| format!(" (simulated makespan {m:.4}s)"))
            .unwrap_or_default()
    );
    // On the tcp fabric each process holds only its own rank's
    // partition; only rank 0's process writes, and what it writes is
    // its local partition (docs/NET.md) — in-process fabrics still
    // merge all ranks.
    if let Some(out) = args.str("out") {
        if cluster.local_ranks().contains(&0) {
            let merged =
                rylon::table::Table::concat_all(outs[0].schema(), &outs)?;
            write_csv(&merged, out, &CsvOptions::default())?;
            println!("wrote {out}");
        }
    }
    Ok(())
}

fn cmd_etl(args: &Args, cfg: &RylonConfig) -> Result<()> {
    let rows = args.usize_or("rows", 200_000);
    let world = args.usize_or("world", cfg.world);
    let artifacts_dir = args
        .str("artifacts")
        .unwrap_or(&cfg.artifacts_dir)
        .to_string();
    // Optional RYF fact source: each rank scans its share of row
    // groups with the pipeline's leading predicate/projection pushed
    // down (zone-map group skips, pruned column payloads).
    let input = args.str("in").map(String::from);
    match &input {
        Some(path) => {
            println!("== rylon etl: scan {path}, {world} ranks ==")
        }
        None => println!("== rylon etl: {rows} rows, {world} ranks =="),
    }

    // The demo ETL: filter → fact ⋈ dim → groupby → global sort.
    let pipeline = Pipeline::new()
        .select("d0 > 0")?
        .join("dim", JoinOptions::inner("id", "id"))
        .groupby(GroupByOptions::new(
            &["id"],
            vec![Agg::sum("d1"), Agg::count("d1"), Agg::mean("d2")],
        ))
        .orderby(vec![rylon::ops::orderby::SortKey::desc("sum_d1")]);

    let timer = rylon::metrics::Timer::start();
    let cluster = make_cluster(args, cfg, world)?;
    let outs = cluster.run(|ctx| {
        let dim = rylon::io::datagen::gen_partition(
            &DataGenSpec {
                rows: (rows / 10).max(1),
                payload_cols: 1,
                key_dist: KeyDist::Sequential,
                seed: 0xD17,
            },
            ctx.rank,
            ctx.size,
        )?;
        let mut env = Env::new();
        env.insert("dim".to_string(), dim);
        match &input {
            Some(path) => pipeline.run_ryf_dist(ctx, path, &env),
            None => {
                let fact = rylon::io::datagen::gen_partition(
                    &DataGenSpec::paper_scaling(rows, 0xFAC7),
                    ctx.rank,
                    ctx.size,
                )?;
                pipeline.run_dist(ctx, &fact, &env)
            }
        }
    })?;
    let total: usize = outs.iter().map(|(t, _)| t.num_rows()).sum();
    let mut phases = rylon::metrics::Phases::new();
    for (_, p) in &outs {
        phases.merge(p);
    }
    cluster.fault_stats().record(&mut phases);
    // Out-of-core traffic (docs/MEMORY.md): bytes and partitions the
    // governed operators spilled under --memory-budget (0 when the
    // budget was unbounded or everything fit).
    phases.count("bytes_spilled", cluster.spilled_bytes());
    phases.count("spill_partitions", cluster.spilled_partitions());
    // Scan-pushdown gauges (docs/STORAGE.md): all 0 unless --in
    // scanned an RYF fact table.
    let scan = cluster.scan_stats();
    phases.count("ryf_groups_total", scan.groups_total);
    phases.count("ryf_groups_skipped", scan.groups_skipped);
    phases.count("ryf_decoded_bytes", scan.decoded_bytes);
    phases.count("ryf_decoded_bytes_avoided", scan.decoded_bytes_avoided);
    phases.count("ryf_pruned_columns", scan.pruned_columns);
    println!(
        "pipeline: {} result rows in {:.3}s wall{}",
        human_count(total as u64),
        timer.seconds(),
        cluster
            .makespan()
            .map(|m| format!(", simulated makespan {m:.4}s"))
            .unwrap_or_default()
    );
    println!("stage seconds (sum over ranks): {}", phases.to_json().to_string());

    // Tensor bridge: featurize rank 0's numeric result columns (the
    // paper's Fig 1 handoff to data analytics).
    let (head, _) = &outs[0];
    if !head.is_empty() {
        let rt = Runtime::open(&artifacts_dir).ok();
        let bridge = match &rt {
            Some(rt) => rylon::runtime::FeaturizeKernel::new(rt),
            None => rylon::runtime::FeaturizeKernel::native(),
        };
        let sum_col = head.column_by_name("sum_d1")?.cast_f64()?;
        let cnt_col = head.column_by_name("count_d1")?.cast_f64()?;
        let rows_n = sum_col.len();
        let mut x = Vec::with_capacity(rows_n * 2);
        for i in 0..rows_n {
            x.push(sum_col[i] as f32);
            x.push(cnt_col[i] as f32);
        }
        let feats = bridge.run(&x, rows_n, 2)?;
        println!(
            "tensor bridge ({}): {}×{} features, mean[0]={:.3} inv_std[0]={:.3}",
            if rt.is_some() { "pjrt" } else { "native" },
            feats.rows,
            feats.cols,
            feats.mean[0],
            feats.inv_std[0]
        );
    }
    println!("head:\n{}", outs[0].0.pretty(5));
    Ok(())
}

fn cmd_bench(args: &Args, cfg: &RylonConfig) -> Result<()> {
    let which = args.req("fig")?;
    let samples = args.usize_or("samples", 3);
    let opts = BenchOpts {
        warmup_iters: 1,
        samples,
    };
    let cost = cfg.cost;
    match which {
        "fig10" => {
            let rows = args.usize_or("rows", 2_000_000);
            let max_world = args.usize_or("max-world", 160);
            let worlds: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64, 128, 160]
                .into_iter()
                .filter(|&w| w <= max_world)
                .collect();
            let r = figures::fig10(
                rows,
                &worlds,
                &["rylon", "spark_sim", "dask_sim", "modin_sim"],
                opts,
                cost,
            )?;
            println!("{}", r.render());
            r.save("fig10")?;
        }
        "fig11" => {
            let world = args.usize_or("max-world", 200);
            let base = args.usize_or("rows", 2_000_000);
            let sweep: Vec<usize> =
                [1usize, 5, 10, 25, 50].iter().map(|&m| base * m).collect();
            let r = figures::fig11(&sweep, world, opts, cost)?;
            println!("{}", r.render());
            r.save("fig11")?;
        }
        "fig12" => {
            let rows = args.usize_or("rows", 2_000_000);
            let rt = Runtime::open(
                args.str("artifacts").unwrap_or(&cfg.artifacts_dir),
            )
            .ok();
            if rt.is_none() {
                eprintln!(
                    "note: artifacts not found — pjrt arm uses native fallback"
                );
            }
            let workers: Vec<usize> = [1, 2, 4, 8, 16, 32, 64, 128, 160]
                .into_iter()
                .filter(|&w| w <= args.usize_or("max-world", 160))
                .collect();
            let r = figures::fig12(rows, &workers, rt.as_ref(), opts)?;
            println!("{}", r.render());
            r.save("fig12")?;
        }
        "ablations" => {
            let rows = args.usize_or("rows", 500_000);
            for (name, r) in [
                (
                    "join_algo",
                    figures::ablation_join_algo(
                        &[rows / 10, rows / 2, rows],
                        opts,
                    )?,
                ),
                (
                    "fabric",
                    figures::ablation_fabric(
                        rows,
                        &[1, 4, 16, 64, 160],
                        &[1e-6, 5e-6, 5e-5],
                        opts,
                    )?,
                ),
                (
                    "chunk",
                    figures::ablation_chunk(
                        rows,
                        16,
                        &[256, 4096, 65536, 1 << 20],
                        opts,
                    )?,
                ),
                (
                    "groupby",
                    figures::ablation_groupby(rows, 16, 1000, opts)?,
                ),
            ] {
                println!("{}", r.render());
                r.save(&format!("ablation_{name}"))?;
            }
        }
        other => {
            return Err(RylonError::invalid(format!(
                "unknown figure '{other}' (fig10|fig11|fig12|ablations)"
            )))
        }
    }
    Ok(())
}

fn cmd_sql(args: &Args) -> Result<()> {
    let query = args.req("query")?;
    let mut env = Env::new();
    for spec in args.req("tables")?.split(',') {
        let (name, path) = spec.split_once('=').ok_or_else(|| {
            RylonError::invalid(format!(
                "bad --tables entry '{spec}' (want name=path.csv)"
            ))
        })?;
        env.insert(
            name.trim().to_string(),
            read_csv(path.trim(), &CsvOptions::default())?,
        );
    }
    let timer = rylon::metrics::Timer::start();
    let out = rylon::sql::execute_local(query, &env)?;
    println!(
        "{} rows in {:.3}s\n{}",
        human_count(out.num_rows() as u64),
        timer.seconds(),
        out.pretty(20)
    );
    if let Some(path) = args.str("out") {
        write_csv(&out, path, &CsvOptions::default())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// RYF → CSV, group at a time: one parsed row group resident at once,
/// rows appended through the incremental [`rylon::io::csv::CsvWriter`]
/// (header once, then data), temp-file + rename like the CSV → RYF
/// direction so a failed conversion never leaves a truncated --out.
fn convert_ryf_to_csv(input: &str, out: &str) -> Result<()> {
    use rylon::io::csv::CsvWriter;
    use rylon::io::ryf::{read_ryf_footer, read_ryf_group};

    let timer = rylon::metrics::Timer::start();
    let tmp = format!("{out}.tmp");
    let mut rows = 0usize;
    let mut convert = || -> Result<(rylon::types::Schema, usize)> {
        let metas = read_ryf_footer(input)?;
        let first_meta = metas
            .first()
            .ok_or_else(|| RylonError::parse("ryf: no groups"))?;
        let first = read_ryf_group(input, first_meta)?;
        let schema = first.schema().clone();
        let mut w = CsvWriter::new(
            std::fs::File::create(&tmp)?,
            &schema,
            &CsvOptions::default(),
        )?;
        rows += first.num_rows();
        w.append(&first)?;
        drop(first);
        for m in metas.iter().skip(1) {
            let t = read_ryf_group(input, m)?;
            if t.schema() != &schema {
                return Err(RylonError::schema(format!(
                    "ryf group schema mismatch: {} vs {}",
                    t.schema(),
                    schema
                )));
            }
            rows += t.num_rows();
            w.append(&t)?;
        }
        w.finish()?;
        std::fs::rename(&tmp, out)?;
        Ok((schema, metas.len()))
    };
    let (schema, groups) = match convert() {
        Ok(r) => r,
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            return Err(e);
        }
    };
    println!(
        "converted {} rows ({}) from {groups} row groups in {:.3}s: {out}",
        human_count(rows as u64),
        schema,
        timer.seconds()
    );
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    use rylon::io::ryf::RyfWriter;
    use rylon::table::Table;

    let input = args.req("in")?;
    let out = args.req("out")?;
    // Direction from the input suffix: .ryf streams groups back out to
    // CSV; anything else is the CSV → RYF ingest direction.
    if input.ends_with(".ryf") {
        return convert_ryf_to_csv(input, out);
    }
    // 0 = one row group per streamed chunk (group sizes then follow the
    // ingest chunk size; boundaries reset per chunk, so explicit
    // --group-rows gives approximate, not exact, group sizes).
    let group_rows = args.usize_or("group-rows", 0);
    let timer = rylon::metrics::Timer::start();
    let f = std::fs::File::open(input)?;
    // Write to a temp path and rename on success, so a mid-stream parse
    // error never leaves a truncated footer-less RYF at --out (or
    // clobbers a previous good conversion).
    let tmp = format!("{out}.tmp");
    let mut rows = 0usize;
    let convert = || -> Result<(rylon::types::Schema, usize)> {
        let mut w = RyfWriter::create(&tmp)?;
        // Streaming conversion: each parsed chunk is appended as row
        // group(s) and dropped, so neither the raw text nor the parsed
        // table is ever whole in memory.
        let schema = rylon::io::csv::read_csv_chunked(
            f,
            &CsvOptions::default(),
            |t| {
                rows += t.num_rows();
                if group_rows == 0 {
                    w.append(&t)
                } else {
                    let groups = t.num_rows().div_ceil(group_rows).max(1);
                    for g in 0..groups {
                        w.append(&t.slice(g * group_rows, group_rows))?;
                    }
                    Ok(())
                }
            },
        )?;
        if w.groups() == 0 {
            // Schema-only file: one empty group carries the schema.
            w.append(&Table::empty(schema.clone()))?;
        }
        let groups = w.finish()?;
        std::fs::rename(&tmp, out)?;
        Ok((schema, groups))
    };
    let (schema, groups) = match convert() {
        Ok(r) => r,
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            return Err(e);
        }
    };
    println!(
        "converted {} rows ({}) into {groups} row groups in {:.3}s: {out}",
        human_count(rows as u64),
        schema,
        timer.seconds()
    );
    Ok(())
}

/// Run every (or one) YAML bench recipe and write a summary JSON per
/// recipe (`docs/STORAGE.md`, `bench/recipes/README.md`). Each run
/// cross-checks the encoded scan against the raw-format oracle and
/// errors on any bit-identity violation, so CI can gate on it.
fn cmd_bench_runall(args: &Args) -> Result<()> {
    let recipes = args.str("recipes").unwrap_or("bench/recipes");
    let out = args.str("out").unwrap_or("bench/results");
    let samples = args.usize_or("samples", 3);
    let summaries = rylon::bench_harness::recipe::run_all(
        recipes,
        out,
        samples,
        args.str("recipe"),
    )?;
    for s in &summaries {
        println!("{}", s.render());
    }
    println!("wrote {} recipe summaries to {out}/", summaries.len());
    Ok(())
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // `bench run-all` is the one positional sub-subcommand: fold it
    // into a synthetic command name so the `--key value` flag parser
    // stays dumb.
    let argv: Vec<String> = if argv.first().map(String::as_str)
        == Some("bench")
        && argv.get(1).map(String::as_str) == Some("run-all")
    {
        std::iter::once("bench-run-all".to_string())
            .chain(argv[2..].iter().cloned())
            .collect()
    } else {
        argv
    };
    let args = Args::parse(&argv)?;
    let cfg = load_config(&args)?;
    // Local (single-process) work — CSV/RYF ingest, local SQL, gather
    // paths in gen/inspect — runs on the main thread: give it the same
    // executor budget a one-rank cluster would get. Cluster commands
    // re-resolve per rank in `make_cluster`.
    rylon::exec::set_intra_op_threads(rylon::exec::resolve_intra_op_threads(
        args.usize_or("intra-threads", cfg.intra_op_threads),
        1,
    ));
    rylon::exec::set_par_row_threshold(
        args.usize_or("par-threshold", cfg.par_row_threshold),
    );
    rylon::exec::set_ingest_chunk_bytes(
        rylon::exec::resolve_ingest_chunk_bytes(
            args.usize_or("ingest-chunk", cfg.ingest_chunk_bytes),
        ),
    );
    rylon::exec::set_ingest_single_pass(
        rylon::exec::resolve_ingest_single_pass(
            args.bool_flag("ingest-single-pass")?
                .or(cfg.ingest_single_pass),
        ),
    );
    // Informational for single-process commands (a lone local pool has
    // nobody to steal from); cluster commands resolve per rank.
    rylon::exec::set_work_steal(rylon::exec::resolve_work_steal(
        args.bool_flag("work-steal")?.or(cfg.work_steal),
    ));
    rylon::exec::set_pipeline_fuse(rylon::exec::resolve_pipeline_fuse(
        args.bool_flag("pipeline-fuse")?.or(cfg.pipeline_fuse),
    ));
    // Picks the RYF write format for local `convert` runs; cluster
    // commands resolve per rank in `make_cluster`.
    rylon::exec::set_ryf_encoding(rylon::exec::resolve_ryf_encoding(
        args.bool_flag("ryf-encoding")?.or(cfg.ryf_encoding),
    ));
    rylon::exec::set_memory_budget_bytes(
        rylon::exec::resolve_memory_budget_bytes(
            args.usize_or("memory-budget", cfg.memory_budget_bytes),
        ),
    );
    match args.cmd.as_str() {
        "gen" => cmd_gen(&args),
        "inspect" => cmd_inspect(&args),
        // Cluster commands on the tcp fabric with no --rank: this
        // process is the launcher, not a rank.
        "join" | "etl" if tcp_launcher_selected(&args, &cfg) => {
            launch_tcp_ranks(&argv, &args, &cfg)
        }
        "join" => cmd_join(&args, &cfg),
        "etl" => cmd_etl(&args, &cfg),
        "bench" => cmd_bench(&args, &cfg),
        "bench-run-all" => cmd_bench_runall(&args),
        "sql" => cmd_sql(&args),
        "convert" => cmd_convert(&args),
        "help" | "-h" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(RylonError::invalid(format!(
            "unknown command '{other}' — try `rylon help`"
        ))),
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("rylon: {e}");
        std::process::exit(1);
    }
}
