//! Physical storage: validity [`Bitmap`]s. Value buffers are plain
//! `Vec<T>` (we own the allocator story end-to-end; Arrow-style shared
//! immutable buffers arrive with zero-copy slicing in `table::slice`,
//! which shares column `Arc`s instead).

mod bitmap;

pub use bitmap::Bitmap;
