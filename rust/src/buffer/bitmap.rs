//! Arrow-style validity bitmap: bit i set ⇒ row i is valid (non-null).
//! `None` bitmap at the column level means "all valid"; this type is only
//! materialised when at least one null exists.

/// Packed little-endian bitmap with a logical length in bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitmap {
    bits: Vec<u64>,
    len: usize,
}

impl Bitmap {
    /// All-valid bitmap of `len` bits.
    pub fn ones(len: usize) -> Bitmap {
        let words = len.div_ceil(64);
        let mut bits = vec![u64::MAX; words];
        Self::mask_tail(&mut bits, len);
        Bitmap { bits, len }
    }

    /// All-null bitmap of `len` bits.
    pub fn zeros(len: usize) -> Bitmap {
        Bitmap {
            bits: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// Build from a bool slice (true = valid).
    pub fn from_bools(vals: &[bool]) -> Bitmap {
        let mut b = Bitmap::zeros(vals.len());
        for (i, &v) in vals.iter().enumerate() {
            if v {
                b.set(i, true);
            }
        }
        b
    }

    fn mask_tail(bits: &mut [u64], len: usize) {
        let tail = len % 64;
        if tail != 0 {
            if let Some(last) = bits.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.bits[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, valid: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if valid {
            self.bits[w] |= 1 << b;
        } else {
            self.bits[w] &= !(1 << b);
        }
    }

    /// Append one bit (used by builders).
    pub fn push(&mut self, valid: bool) {
        if self.len % 64 == 0 {
            self.bits.push(0);
        }
        self.len += 1;
        if valid {
            self.set(self.len - 1, true);
        }
    }

    /// Number of valid (set) bits — popcount over words.
    pub fn count_ones(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Number of nulls.
    pub fn count_zeros(&self) -> usize {
        self.len - self.count_ones()
    }

    /// True if every bit is set (column can drop its bitmap).
    pub fn all_valid(&self) -> bool {
        self.count_ones() == self.len
    }

    /// Gather: new bitmap with `out[i] = self[indices[i]]`.
    pub fn take(&self, indices: &[usize]) -> Bitmap {
        let mut out = Bitmap::zeros(indices.len());
        for (i, &idx) in indices.iter().enumerate() {
            if self.get(idx) {
                out.set(i, true);
            }
        }
        out
    }

    /// Contiguous slice `[offset, offset+len)`.
    pub fn slice(&self, offset: usize, len: usize) -> Bitmap {
        assert!(offset + len <= self.len);
        let mut out = Bitmap::zeros(len);
        for i in 0..len {
            if self.get(offset + i) {
                out.set(i, true);
            }
        }
        out
    }

    /// Concatenate two bitmaps.
    pub fn concat(&self, other: &Bitmap) -> Bitmap {
        let mut out = Bitmap::zeros(self.len + other.len);
        for i in 0..self.len {
            if self.get(i) {
                out.set(i, true);
            }
        }
        for i in 0..other.len {
            if other.get(i) {
                out.set(self.len + i, true);
            }
        }
        out
    }

    /// Iterate bits as bools.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// Serialize to words (wire format for the shuffle).
    pub fn words(&self) -> &[u64] {
        &self.bits
    }

    /// Mutable word access for the parallel gather kernels, whose
    /// workers write disjoint word ranges. Bits at or past `len` must
    /// stay zero (the tail-mask invariant).
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.bits
    }

    /// Rebuild from wire words + logical length.
    pub fn from_words(words: Vec<u64>, len: usize) -> Bitmap {
        assert_eq!(words.len(), len.div_ceil(64));
        let mut bits = words;
        Self::mask_tail(&mut bits, len);
        Bitmap { bits, len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ones_zeros_counts() {
        let o = Bitmap::ones(70);
        assert_eq!(o.count_ones(), 70);
        assert!(o.all_valid());
        let z = Bitmap::zeros(70);
        assert_eq!(z.count_ones(), 0);
        assert_eq!(z.count_zeros(), 70);
    }

    #[test]
    fn set_get_push() {
        let mut b = Bitmap::zeros(0);
        for i in 0..130 {
            b.push(i % 3 == 0);
        }
        assert_eq!(b.len(), 130);
        for i in 0..130 {
            assert_eq!(b.get(i), i % 3 == 0, "bit {i}");
        }
        b.set(1, true);
        assert!(b.get(1));
        b.set(0, false);
        assert!(!b.get(0));
    }

    #[test]
    fn tail_bits_masked() {
        let b = Bitmap::ones(65);
        assert_eq!(b.count_ones(), 65);
        // Word 1 must only have 1 bit set.
        assert_eq!(b.words()[1], 1);
    }

    #[test]
    fn take_slice_concat() {
        let b = Bitmap::from_bools(&[true, false, true, true, false]);
        let t = b.take(&[4, 2, 0]);
        assert_eq!(
            t.iter().collect::<Vec<_>>(),
            vec![false, true, true]
        );
        let s = b.slice(1, 3);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![false, true, true]);
        let c = s.concat(&t);
        assert_eq!(c.len(), 6);
        assert_eq!(
            c.iter().collect::<Vec<_>>(),
            vec![false, true, true, false, true, true]
        );
    }

    #[test]
    fn words_roundtrip() {
        let b = Bitmap::from_bools(&[true, true, false, true]);
        let b2 = Bitmap::from_words(b.words().to_vec(), b.len());
        assert_eq!(b, b2);
    }
}
