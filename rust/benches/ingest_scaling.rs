//! Ingest (streaming morsel-parallel parse) scaling: CSV and RYF reads
//! on one rank at 1/2/4/8 worker threads, over a table with nullable
//! and string columns so the full gather/builder surface is exercised,
//! plus a **chunk-size sweep** (256 KiB → 16 MiB) of the streaming CSV
//! reader with **peak-RSS** alongside throughput — the bounded-memory
//! claim made measurable: streamed ingest peaks near
//! O(chunk × workers) + parsed table, while the whole-buffer reference
//! additionally holds the entire raw file.
//!
//! Verifies the parallel/streamed parses are bit-identical to serial
//! before any timing counts, prints the rows/sec grid, and emits
//! `BENCH_ingest.json` (mirror of `intra_op_scaling.rs` →
//! `BENCH_intra_op.json`).
//!
//! A **scan-selectivity sweep** (0.1% / 1% / 10% / 100%) scans the
//! same table through the zone-map pushdown path (docs/STORAGE.md) in
//! both RYF formats, asserting bit-identity and reporting
//! `groups_skipped`, `decoded_bytes_avoided`, and
//! `speedup_encoded_vs_raw` per selectivity.
//!
//! Env overrides: INGEST_ROWS (default 500_000), INGEST_SAMPLES,
//! INGEST_MAX_THREADS.

use rylon::bench_harness::{
    measure, peak_rss_bytes, reset_peak_rss, BenchOpts, Report,
};
use rylon::column::Column;
use rylon::dist::{
    read_csv_partition_with, Cluster, DistConfig, IngestMode, IngestStats,
};
use rylon::exec;
use rylon::io::csv::{read_csv, read_csv_str, write_csv, CsvOptions};
use rylon::io::ryf::{read_ryf, scan_ryf, write_ryf, ScanOptions};
use rylon::ops::select::Predicate;
use rylon::table::Table;
use rylon::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// The workload shape the paper loads (§V): an integer key, a numeric
/// payload (with nulls), and a string column (with empties + quoting).
fn make_table(rows: usize) -> Table {
    Table::from_columns(vec![
        ("id", Column::from_i64((0..rows as i64).collect())),
        (
            "v",
            Column::from_opt_f64(
                (0..rows)
                    .map(|i| {
                        if i % 13 == 0 {
                            None
                        } else {
                            Some(i as f64 * 0.5 - 1000.0)
                        }
                    })
                    .collect(),
            ),
        ),
        (
            // No empty strings here: CSV renders both them and nulls as
            // empty cells, which would break the roundtrip assert.
            "s",
            Column::from_str(
                &(0..rows)
                    .map(|i| match i % 7 {
                        0 => format!("quoted,{i}"),
                        1 => format!("esc\"{i}"),
                        _ => format!("name-{i}"),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap()
}

/// Measure `run` under `opts`, also sampling the phase's peak RSS
/// (watermark reset before the timed runs where the kernel allows).
fn measure_with_rss(
    opts: BenchOpts,
    run: &dyn Fn() -> Table,
) -> (f64, f64) {
    reset_peak_rss();
    let stats = measure(opts, || {
        std::hint::black_box(run().num_rows());
    });
    let rss = peak_rss_bytes().unwrap_or(0) as f64;
    (stats.median, rss)
}

fn main() {
    let rows = env_usize("INGEST_ROWS", 500_000);
    let max_threads = env_usize("INGEST_MAX_THREADS", 8);
    let opts = BenchOpts {
        warmup_iters: 1,
        samples: env_usize("INGEST_SAMPLES", 3),
    };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads_sweep: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    println!(
        "ingest scaling: {rows} rows, {cores} cores, threads {threads_sweep:?}"
    );

    let table = make_table(rows);
    let dir = std::env::temp_dir();
    let csv_path = dir.join("rylon_ingest_scaling.csv");
    let ryf_path = dir.join("rylon_ingest_scaling.ryf");
    write_csv(&table, &csv_path, &CsvOptions::default()).expect("write csv");
    // Enough row groups that an 8-way read never starves.
    write_ryf(&table, &ryf_path, (rows / 64).max(1)).expect("write ryf");
    let file_bytes = std::fs::metadata(&csv_path)
        .map(|m| m.len())
        .unwrap_or(0);

    type Loader = Box<dyn Fn() -> Table>;
    let workloads: Vec<(&str, Loader)> = vec![
        ("csv_parse", {
            let p = csv_path.clone();
            Box::new(move || read_csv(&p, &CsvOptions::default()).unwrap())
        }),
        ("ryf_read", {
            let p = ryf_path.clone();
            Box::new(move || read_ryf(&p).unwrap())
        }),
    ];

    let mut report = Report::new(&format!(
        "Streaming morsel-parallel ingest scaling, {rows} rows ({cores} cores)"
    ));
    let mut results: Vec<Json> = Vec::new();

    for (name, run) in &workloads {
        // Serial reference — every thread count must reproduce it
        // bit-for-bit before its timing counts.
        let reference = exec::with_intra_op_threads(1, run);
        assert_eq!(
            reference, table,
            "{name} roundtrip must reproduce the generated table"
        );
        let mut base_seconds = f64::NAN;
        for &t in &threads_sweep {
            let out = exec::with_intra_op_threads(t, run);
            assert_eq!(
                out, reference,
                "{name} at {t} threads diverged from serial"
            );
            let (median, rss) = exec::with_intra_op_threads(t, || {
                measure_with_rss(opts, run)
            });
            if t == 1 {
                base_seconds = median;
            }
            let rows_per_sec = rows as f64 / median.max(1e-12);
            let speedup = base_seconds / median.max(1e-12);
            report.add_with(
                name,
                t as f64,
                median,
                vec![
                    ("rows_per_sec".to_string(), rows_per_sec),
                    ("speedup_vs_1t".to_string(), speedup),
                    ("peak_rss_bytes".to_string(), rss),
                ],
            );
            results.push(Json::obj(vec![
                ("op", Json::str(name.to_string())),
                ("threads", Json::num(t as f64)),
                ("seconds", Json::num(median)),
                ("rows_per_sec", Json::num(rows_per_sec)),
                ("speedup_vs_1t", Json::num(speedup)),
                ("peak_rss_bytes", Json::num(rss)),
            ]));
            println!(
                "  {:>10} t={t}: {:>10.4}s  {:>14.0} rows/s  ({:.2}x vs 1t)  rss {:>6.1} MiB",
                name,
                median,
                rows_per_sec,
                speedup,
                rss / (1024.0 * 1024.0)
            );
        }
    }

    // Chunk-size sweep: the streaming reader at 256 KiB → 16 MiB
    // chunks, plus the whole-buffer reference, all at the same thread
    // budget — peak RSS alongside throughput makes the memory bound
    // visible (streamed raw text is O(chunk), whole-buffer is O(file)).
    let sweep_threads = *threads_sweep.last().unwrap_or(&1);
    let reference = exec::with_intra_op_threads(1, || {
        read_csv(&csv_path, &CsvOptions::default()).unwrap()
    });
    println!(
        "chunk sweep ({} B file, t={sweep_threads}):",
        file_bytes
    );
    for chunk in [256 << 10, 1 << 20, 4 << 20, 16 << 20] {
        let p = csv_path.clone();
        let run: Loader = Box::new(move || {
            read_csv(&p, &CsvOptions::default()).unwrap()
        });
        let out = exec::with_intra_op_threads(sweep_threads, || {
            exec::with_ingest_chunk_bytes(chunk, || run())
        });
        assert_eq!(
            out, reference,
            "streamed parse diverged at chunk {chunk}"
        );
        let (median, rss) = exec::with_intra_op_threads(sweep_threads, || {
            exec::with_ingest_chunk_bytes(chunk, || {
                measure_with_rss(opts, &run)
            })
        });
        let rows_per_sec = rows as f64 / median.max(1e-12);
        report.add_with(
            "csv_stream_chunk",
            chunk as f64,
            median,
            vec![
                ("rows_per_sec".to_string(), rows_per_sec),
                ("peak_rss_bytes".to_string(), rss),
            ],
        );
        results.push(Json::obj(vec![
            ("op", Json::str("csv_stream_chunk".to_string())),
            ("chunk_bytes", Json::num(chunk as f64)),
            ("threads", Json::num(sweep_threads as f64)),
            ("seconds", Json::num(median)),
            ("rows_per_sec", Json::num(rows_per_sec)),
            ("peak_rss_bytes", Json::num(rss)),
        ]));
        println!(
            "  chunk {:>9}: {:>10.4}s  {:>14.0} rows/s  rss {:>6.1} MiB",
            chunk,
            median,
            rows_per_sec,
            rss / (1024.0 * 1024.0)
        );
    }
    // Whole-buffer reference arm: slurps the file, then parses.
    {
        let p = csv_path.clone();
        let run: Loader = Box::new(move || {
            let text = std::fs::read_to_string(&p).unwrap();
            read_csv_str(&text, &CsvOptions::default()).unwrap()
        });
        let out = exec::with_intra_op_threads(sweep_threads, || run());
        assert_eq!(out, reference, "whole-buffer parse diverged");
        let (median, rss) = exec::with_intra_op_threads(sweep_threads, || {
            measure_with_rss(opts, &run)
        });
        let rows_per_sec = rows as f64 / median.max(1e-12);
        report.add_with(
            "csv_whole_buffer",
            file_bytes as f64,
            median,
            vec![
                ("rows_per_sec".to_string(), rows_per_sec),
                ("peak_rss_bytes".to_string(), rss),
            ],
        );
        results.push(Json::obj(vec![
            ("op", Json::str("csv_whole_buffer".to_string())),
            ("chunk_bytes", Json::num(file_bytes as f64)),
            ("threads", Json::num(sweep_threads as f64)),
            ("seconds", Json::num(median)),
            ("rows_per_sec", Json::num(rows_per_sec)),
            ("peak_rss_bytes", Json::num(rss)),
        ]));
        println!(
            "  whole-buffer: {:>10.4}s  {:>14.0} rows/s  rss {:>6.1} MiB",
            median,
            rows_per_sec,
            rss / (1024.0 * 1024.0)
        );
    }

    // Distributed arm: single-pass byte-range ingest vs the two-pass
    // count-then-parse oracle. Two-pass reads world × file count-pass
    // bytes plus parse passes that stop at each rank's block end
    // (≈ file × (world+1)/2 more); single-pass reads exactly file
    // bytes — the wall-clock gap is PR 4's headline number.
    // Bit-identity and the byte counter are asserted before any
    // timing counts.
    for world in [2usize, 4] {
        let cluster =
            Cluster::new(DistConfig::threads(world)).expect("cluster");
        let byte_stats = IngestStats::new();
        let sp = cluster
            .run(|ctx| {
                read_csv_partition_with(
                    ctx,
                    &csv_path,
                    &CsvOptions::default(),
                    IngestMode::SinglePass,
                    Some(&byte_stats),
                )
            })
            .expect("single-pass ingest");
        assert_eq!(
            byte_stats.bytes_read(),
            file_bytes,
            "single-pass must read each byte exactly once"
        );
        let tp = cluster
            .run(|ctx| {
                read_csv_partition_with(
                    ctx,
                    &csv_path,
                    &CsvOptions::default(),
                    IngestMode::TwoPass,
                    None,
                )
            })
            .expect("two-pass ingest");
        assert_eq!(sp, tp, "dist ingest modes diverged at world {world}");

        let time_mode = |mode: IngestMode| {
            measure(opts, || {
                let outs = cluster
                    .run(|ctx| {
                        read_csv_partition_with(
                            ctx,
                            &csv_path,
                            &CsvOptions::default(),
                            mode,
                            None,
                        )
                    })
                    .expect("dist ingest");
                std::hint::black_box(outs.len());
            })
            .median
        };
        let sp_med = time_mode(IngestMode::SinglePass);
        let tp_med = time_mode(IngestMode::TwoPass);
        let speedup = tp_med / sp_med.max(1e-12);
        for (op, med) in [
            ("dist_ingest_single_pass", sp_med),
            ("dist_ingest_two_pass", tp_med),
        ] {
            let rows_per_sec = rows as f64 / med.max(1e-12);
            report.add_with(
                op,
                world as f64,
                med,
                vec![
                    ("rows_per_sec".to_string(), rows_per_sec),
                    (
                        "speedup_single_vs_two_pass".to_string(),
                        speedup,
                    ),
                ],
            );
            results.push(Json::obj(vec![
                ("op", Json::str(op.to_string())),
                ("world", Json::num(world as f64)),
                ("seconds", Json::num(med)),
                ("rows_per_sec", Json::num(rows_per_sec)),
                ("speedup_single_vs_two_pass", Json::num(speedup)),
            ]));
        }
        println!(
            "  dist world={world}: single-pass {:>8.4}s  two-pass \
             {:>8.4}s  ({speedup:.2}x)",
            sp_med, tp_med
        );
    }

    // Scan-selectivity sweep: the sequential `id` column makes zone
    // maps ideal, so `id < k` prunes every group past the cutoff
    // without decoding. Encoded and raw files are scanned with the
    // same predicate + projection; bit-identity is asserted before
    // timing, and the counters that justify the encoded format
    // (groups skipped, decoded bytes avoided) ride along.
    let enc_scan_path = dir.join("rylon_ingest_scan_enc.ryf");
    let raw_scan_path = dir.join("rylon_ingest_scan_raw.ryf");
    let group_rows = (rows / 64).max(1);
    exec::with_ryf_encoding(true, || {
        write_ryf(&table, &enc_scan_path, group_rows)
    })
    .expect("write encoded ryf");
    exec::with_ryf_encoding(false, || {
        write_ryf(&table, &raw_scan_path, group_rows)
    })
    .expect("write raw ryf");
    println!(
        "scan selectivity sweep ({} rows/group, t={sweep_threads}):",
        group_rows
    );
    for selectivity in [0.001f64, 0.01, 0.1, 1.0] {
        let cutoff = ((rows as f64) * selectivity).round() as i64;
        let sopts = ScanOptions {
            predicate: Some(
                Predicate::parse(&format!("id < {cutoff}")).unwrap(),
            ),
            projection: Some(vec!["id".to_string(), "v".to_string()]),
        };
        let _ = exec::take_scan_stats();
        let (enc_out, sc) = exec::with_intra_op_threads(sweep_threads, || {
            let out = scan_ryf(&enc_scan_path, &sopts).unwrap();
            (out, exec::take_scan_stats())
        });
        let raw_out = exec::with_intra_op_threads(sweep_threads, || {
            scan_ryf(&raw_scan_path, &sopts).unwrap()
        });
        let _ = exec::take_scan_stats();
        assert_eq!(
            enc_out, raw_out,
            "encoded scan diverged from the raw oracle at \
             selectivity {selectivity}"
        );
        let rows_out = enc_out.num_rows();
        let time_scan = |path: &std::path::Path| {
            let p = path.to_path_buf();
            exec::with_intra_op_threads(sweep_threads, || {
                let med = measure(opts, || {
                    std::hint::black_box(
                        scan_ryf(&p, &sopts).unwrap().num_rows(),
                    );
                })
                .median;
                let _ = exec::take_scan_stats();
                med
            })
        };
        let enc_med = time_scan(&enc_scan_path);
        let raw_med = time_scan(&raw_scan_path);
        let speedup = raw_med / enc_med.max(1e-12);
        report.add_with(
            "ryf_scan_selectivity",
            selectivity,
            enc_med,
            vec![
                ("raw_seconds".to_string(), raw_med),
                ("speedup_encoded_vs_raw".to_string(), speedup),
                ("groups_skipped".to_string(), sc.groups_skipped as f64),
                (
                    "decoded_bytes_avoided".to_string(),
                    sc.decoded_bytes_avoided as f64,
                ),
                ("rows_out".to_string(), rows_out as f64),
            ],
        );
        results.push(Json::obj(vec![
            ("op", Json::str("ryf_scan_selectivity".to_string())),
            ("selectivity", Json::num(selectivity)),
            ("threads", Json::num(sweep_threads as f64)),
            ("seconds", Json::num(enc_med)),
            ("raw_seconds", Json::num(raw_med)),
            ("speedup_encoded_vs_raw", Json::num(speedup)),
            ("groups_total", Json::num(sc.groups_total as f64)),
            ("groups_skipped", Json::num(sc.groups_skipped as f64)),
            (
                "decoded_bytes_avoided",
                Json::num(sc.decoded_bytes_avoided as f64),
            ),
            ("pruned_columns", Json::num(sc.pruned_columns as f64)),
            ("rows_out", Json::num(rows_out as f64)),
        ]));
        println!(
            "  sel {:>6.3}%: enc {:>9.4}s  raw {:>9.4}s  \
             ({speedup:.2}x)  skipped {}/{}  avoided {:>6.1} MiB",
            selectivity * 100.0,
            enc_med,
            raw_med,
            sc.groups_skipped,
            sc.groups_total,
            sc.decoded_bytes_avoided as f64 / (1024.0 * 1024.0)
        );
    }
    std::fs::remove_file(&enc_scan_path).ok();
    std::fs::remove_file(&raw_scan_path).ok();

    println!("{}", report.render());
    report.save("ingest_scaling").expect("save report");

    let json = Json::obj(vec![
        ("rows", Json::num(rows as f64)),
        ("cores", Json::num(cores as f64)),
        ("file_bytes", Json::num(file_bytes as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_ingest.json", json.to_string())
        .expect("write BENCH_ingest.json");
    println!("wrote BENCH_ingest.json");

    std::fs::remove_file(&csv_path).ok();
    std::fs::remove_file(&ryf_path).ok();
}
