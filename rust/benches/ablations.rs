//! Ablations over the design choices DESIGN.md calls out:
//!   * join algorithm (sort vs hash) on the local hot path;
//!   * network latency α sweep — moves the Fig 10 plateau (§V-1's
//!     communication-bound argument);
//!   * shuffle chunk size — streaming vs buffered AllToAll
//!     (backpressure knob);
//!   * dist groupby strategy — shuffle-all vs local pre-aggregation.
//!
//! Env overrides: ABL_ROWS (default 500_000), ABL_SAMPLES.

use rylon::bench_harness::{figures, BenchOpts};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let rows = env_usize("ABL_ROWS", 500_000);
    let opts = BenchOpts {
        warmup_iters: 1,
        samples: env_usize("ABL_SAMPLES", 3),
    };

    let r = figures::ablation_join_algo(&[rows / 10, rows / 2, rows], opts)
        .expect("join_algo");
    println!("{}", r.render());
    r.save("ablation_join_algo").expect("save");

    let r = figures::ablation_fabric(
        rows,
        &[1, 4, 16, 64, 160],
        &[1e-6, 5e-6, 5e-5],
        opts,
    )
    .expect("fabric");
    println!("{}", r.render());
    r.save("ablation_fabric").expect("save");

    let r = figures::ablation_chunk(
        rows,
        16,
        &[256, 4096, 65_536, 1 << 20],
        opts,
    )
    .expect("chunk");
    println!("{}", r.render());
    r.save("ablation_chunk").expect("save");

    let r = figures::ablation_groupby(rows, 16, 1000, opts)
        .expect("groupby");
    println!("{}", r.render());
    r.save("ablation_groupby").expect("save");
}
