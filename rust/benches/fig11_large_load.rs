//! Fig 11 — larger loads at fixed parallelism (paper §V-2): 200 ranks,
//! total rows swept upward; the paper reports the PySpark/Cylon time
//! ratio growing from ~2.1× to ~4.5×. We sweep 1×..50× a base size and
//! report the same ratio column.
//!
//! Env overrides: FIG11_BASE_ROWS (default 2_000_000; paper's sweep is
//! 200M → 10B), FIG11_WORLD (default 200), FIG11_SAMPLES.

use rylon::bench_harness::{figures, BenchOpts};
use rylon::net::CostModel;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let base = env_usize("FIG11_BASE_ROWS", 500_000);
    let world = env_usize("FIG11_WORLD", 200);
    let samples = env_usize("FIG11_SAMPLES", 3);
    let sweep: Vec<usize> =
        [1usize, 5, 10, 25, 50].iter().map(|&m| base * m).collect();
    let report = figures::fig11(
        &sweep,
        world,
        BenchOpts {
            warmup_iters: 1,
            samples,
        },
        CostModel::default(),
    )
    .expect("fig11");
    println!("{}", report.render());
    // Print the headline ratio series explicitly.
    println!("rows -> spark/rylon ratio:");
    for s in report.samples.iter().filter(|s| !s.extra.is_empty()) {
        println!("  {:>12}: {:.2}x", s.x, s.extra[0].1);
    }
    report.save("fig11").expect("save");
}
