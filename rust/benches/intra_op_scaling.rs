//! Intra-op (morsel) scaling: filter / hash join / groupby / sort on
//! one rank at 1/2/4/8 worker threads over `io::datagen` tables.
//! Verifies parallel output is bit-identical to serial, prints the
//! rows/sec grid, and emits `BENCH_intra_op.json` so the perf
//! trajectory is tracked from this PR onward.
//!
//! Env overrides: INTRA_ROWS (default 1_000_000), INTRA_SAMPLES,
//! INTRA_MAX_THREADS.

use rylon::bench_harness::{measure, BenchOpts, Report};
use rylon::exec;
use rylon::io::datagen::{gen_table, DataGenSpec};
use rylon::ops::groupby::{groupby, Agg, GroupByOptions};
use rylon::ops::join::{join, JoinAlgo, JoinOptions};
use rylon::ops::orderby::{orderby, SortKey};
use rylon::ops::select::{select, Predicate};
use rylon::table::Table;
use rylon::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

struct Workload {
    name: &'static str,
    rows: usize,
    run: Box<dyn Fn() -> Table>,
}

fn main() {
    let rows = env_usize("INTRA_ROWS", 1_000_000);
    let max_threads = env_usize("INTRA_MAX_THREADS", 8);
    let opts = BenchOpts {
        warmup_iters: 1,
        samples: env_usize("INTRA_SAMPLES", 3),
    };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads_sweep: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    println!(
        "intra-op scaling: {rows} rows, {cores} cores, threads {threads_sweep:?}"
    );

    let a = gen_table(&DataGenSpec::paper_scaling(rows, 1)).unwrap();
    let b = gen_table(&DataGenSpec::paper_scaling(rows, 2)).unwrap();

    let pred = Predicate::parse("d0 > 0").unwrap();
    let jopts = JoinOptions::inner("id", "id").with_algo(JoinAlgo::Hash);
    let gopts =
        GroupByOptions::new(&["id"], vec![Agg::sum("d1"), Agg::count("d1")]);
    let sort_keys = vec![SortKey::asc("id")];

    let workloads: Vec<Workload> = vec![
        Workload {
            name: "filter",
            rows,
            run: {
                let a = a.clone();
                let pred = pred.clone();
                Box::new(move || select(&a, &pred).unwrap())
            },
        },
        Workload {
            name: "hash_join",
            rows,
            run: {
                let (a, b, jopts) = (a.clone(), b.clone(), jopts.clone());
                Box::new(move || join(&a, &b, &jopts).unwrap())
            },
        },
        Workload {
            name: "groupby",
            rows,
            run: {
                let (a, gopts) = (a.clone(), gopts.clone());
                Box::new(move || groupby(&a, &gopts).unwrap())
            },
        },
        Workload {
            name: "sort",
            rows,
            run: {
                let (a, sort_keys) = (a.clone(), sort_keys.clone());
                Box::new(move || orderby(&a, &sort_keys).unwrap())
            },
        },
    ];

    let mut report = Report::new(&format!(
        "Intra-op morsel scaling, {rows} rows ({cores} cores)"
    ));
    let mut samples: Vec<(String, usize, f64, f64)> = Vec::new();

    for w in &workloads {
        // Serial reference output — every thread count must match it
        // bit-for-bit before its timing counts.
        let reference = exec::with_intra_op_threads(1, || (w.run)());
        let mut base_seconds = f64::NAN;
        for &t in &threads_sweep {
            let out = exec::with_intra_op_threads(t, || (w.run)());
            assert_eq!(
                out, reference,
                "{} at {t} threads diverged from serial",
                w.name
            );
            let stats = exec::with_intra_op_threads(t, || {
                measure(opts, || {
                    std::hint::black_box((w.run)().num_rows());
                })
            });
            if t == 1 {
                base_seconds = stats.median;
            }
            let rows_per_sec = w.rows as f64 / stats.median.max(1e-12);
            let speedup = base_seconds / stats.median.max(1e-12);
            report.add_with(
                w.name,
                t as f64,
                stats.median,
                vec![
                    ("rows_per_sec".to_string(), rows_per_sec),
                    ("speedup_vs_1t".to_string(), speedup),
                ],
            );
            samples.push((w.name.to_string(), t, stats.median, rows_per_sec));
            println!(
                "  {:>10} t={t}: {:>10.4}s  {:>14.0} rows/s  ({:.2}x vs 1t)",
                w.name, stats.median, rows_per_sec, speedup
            );
        }
    }

    println!("{}", report.render());
    report.save("intra_op_scaling").expect("save report");

    // Headline JSON tracked in-repo style: BENCH_intra_op.json.
    let json = Json::obj(vec![
        ("rows", Json::num(rows as f64)),
        ("cores", Json::num(cores as f64)),
        (
            "results",
            Json::Arr(
                samples
                    .iter()
                    .map(|(name, t, secs, rps)| {
                        Json::obj(vec![
                            ("op", Json::str(name.clone())),
                            ("threads", Json::num(*t as f64)),
                            ("seconds", Json::num(*secs)),
                            ("rows_per_sec", Json::num(*rps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_intra_op.json", json.to_string())
        .expect("write BENCH_intra_op.json");
    println!("wrote BENCH_intra_op.json");
}
