//! Intra-op (morsel) scaling: filter / hash join / groupby / sort on
//! one rank at 1/2/4/8 worker threads over `io::datagen` tables.
//! Verifies parallel output is bit-identical to serial, prints the
//! rows/sec grid, and emits `BENCH_intra_op.json` so the perf
//! trajectory is tracked from this PR onward.
//!
//! A second **skew arm** gives one rank 8× the rows of its siblings
//! and times the cluster with cross-rank work stealing on vs off
//! (`speedup_steal_vs_isolated` + per-op steal counts in the JSON),
//! asserting bit-identical outputs between the two schedulers first.
//!
//! A third **fused-pipeline arm** runs one select→project→probe→
//! partial-agg chain through the fused morsel executor and through
//! the operator-at-a-time executor (`[exec] pipeline_fuse` on/off,
//! see `docs/PIPELINE.md`), asserts the outputs bit-identical, and
//! reports `speedup_fused_vs_materialized` per thread count plus the
//! intermediate `Table` bytes fusion never allocates
//! (`intermediate_bytes_avoided`) under a `fused_pipeline` JSON key.
//! Target: ≥1.2× at 4 threads.
//!
//! A fourth **fault-layer arm** times the same rendezvous storm
//! through a raw `LocalFabric` and through `CheckedFabric` (the
//! per-rank Ok/Err verdict every collective now carries, see
//! `docs/FAULTS.md`), reporting per-exchange µs and the verdict
//! overhead under a `fault_layer` key in the JSON.
//!
//! Env overrides: INTRA_ROWS (default 1_000_000), INTRA_SAMPLES,
//! INTRA_MAX_THREADS, INTRA_SKEW_WORLD, INTRA_SKEW_THREADS,
//! INTRA_SKEW_ROWS, INTRA_FAULT_WORLD, INTRA_FAULT_EXCHANGES.

use std::sync::Arc;

use rylon::bench_harness::{measure, BenchOpts, Report};
use rylon::column::Column;
use rylon::compute::filter::take_parallel;
use rylon::dist::{Cluster, DistConfig};
use rylon::exec;
use rylon::net::checked::CheckedFabric;
use rylon::net::local::LocalFabric;
use rylon::net::FabricRef;
use rylon::io::datagen::{gen_table, DataGenSpec, KeyDist};
use rylon::ops::groupby::{groupby, Agg, GroupByOptions};
use rylon::ops::join::{join, JoinAlgo, JoinOptions};
use rylon::ops::orderby::{orderby, SortKey};
use rylon::ops::select::{select, Predicate};
use rylon::table::Table;
use rylon::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

struct Workload {
    name: &'static str,
    rows: usize,
    run: Box<dyn Fn() -> Table>,
}

/// Per-rank table for the skew arm: join-key ids, an f64 payload, and
/// a string column so the gather (materialisation) half of every
/// operator moves real payload bytes.
fn skew_table(rows: usize, seed: u64) -> Table {
    let base = gen_table(&DataGenSpec::paper_scaling(rows, seed)).unwrap();
    let id = base.column_by_name("id").unwrap().i64_values().to_vec();
    let d0 = base.column_by_name("d0").unwrap().f64_values().to_vec();
    let s: Vec<String> = id
        .iter()
        .enumerate()
        .map(|(i, k)| format!("row-{k}-{i}"))
        .collect();
    Table::from_columns(vec![
        ("id", Column::from_i64(id)),
        ("d0", Column::from_f64(d0)),
        (
            "s",
            Column::from_str(
                &s.iter().map(|x| x.as_str()).collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap()
}

fn main() {
    let rows = env_usize("INTRA_ROWS", 1_000_000);
    let max_threads = env_usize("INTRA_MAX_THREADS", 8);
    let opts = BenchOpts {
        warmup_iters: 1,
        samples: env_usize("INTRA_SAMPLES", 3),
    };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads_sweep: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    println!(
        "intra-op scaling: {rows} rows, {cores} cores, threads {threads_sweep:?}"
    );

    let a = gen_table(&DataGenSpec::paper_scaling(rows, 1)).unwrap();
    let b = gen_table(&DataGenSpec::paper_scaling(rows, 2)).unwrap();

    let pred = Predicate::parse("d0 > 0").unwrap();
    let jopts = JoinOptions::inner("id", "id").with_algo(JoinAlgo::Hash);
    let gopts =
        GroupByOptions::new(&["id"], vec![Agg::sum("d1"), Agg::count("d1")]);
    let sort_keys = vec![SortKey::asc("id")];

    let workloads: Vec<Workload> = vec![
        Workload {
            name: "filter",
            rows,
            run: {
                let a = a.clone();
                let pred = pred.clone();
                Box::new(move || select(&a, &pred).unwrap())
            },
        },
        Workload {
            name: "hash_join",
            rows,
            run: {
                let (a, b, jopts) = (a.clone(), b.clone(), jopts.clone());
                Box::new(move || join(&a, &b, &jopts).unwrap())
            },
        },
        Workload {
            name: "groupby",
            rows,
            run: {
                let (a, gopts) = (a.clone(), gopts.clone());
                Box::new(move || groupby(&a, &gopts).unwrap())
            },
        },
        Workload {
            name: "sort",
            rows,
            run: {
                let (a, sort_keys) = (a.clone(), sort_keys.clone());
                Box::new(move || orderby(&a, &sort_keys).unwrap())
            },
        },
    ];

    let mut report = Report::new(&format!(
        "Intra-op morsel scaling, {rows} rows ({cores} cores)"
    ));
    let mut samples: Vec<(String, usize, f64, f64)> = Vec::new();

    for w in &workloads {
        // Serial reference output — every thread count must match it
        // bit-for-bit before its timing counts.
        let reference = exec::with_intra_op_threads(1, || (w.run)());
        let mut base_seconds = f64::NAN;
        for &t in &threads_sweep {
            let out = exec::with_intra_op_threads(t, || (w.run)());
            assert_eq!(
                out, reference,
                "{} at {t} threads diverged from serial",
                w.name
            );
            let stats = exec::with_intra_op_threads(t, || {
                measure(opts, || {
                    std::hint::black_box((w.run)().num_rows());
                })
            });
            if t == 1 {
                base_seconds = stats.median;
            }
            let rows_per_sec = w.rows as f64 / stats.median.max(1e-12);
            let speedup = base_seconds / stats.median.max(1e-12);
            report.add_with(
                w.name,
                t as f64,
                stats.median,
                vec![
                    ("rows_per_sec".to_string(), rows_per_sec),
                    ("speedup_vs_1t".to_string(), speedup),
                ],
            );
            samples.push((w.name.to_string(), t, stats.median, rows_per_sec));
            println!(
                "  {:>10} t={t}: {:>10.4}s  {:>14.0} rows/s  ({:.2}x vs 1t)",
                w.name, stats.median, rows_per_sec, speedup
            );
        }
    }

    // ---- Skew arm: one rank holds 8× the rows of its siblings ----
    //
    // With isolated per-rank pools ("steal off"), the hot rank's
    // morsels can only run on its own workers while every sibling's
    // workers sit idle once their small partitions drain; with
    // work stealing on, those idle workers claim the hot rank's
    // queued morsels. At the default 1 worker per rank the isolated
    // scheduler is exactly the paper's serial-rank model, so the gap
    // is pure scheduling, not extra threads.
    let skew_world = env_usize("INTRA_SKEW_WORLD", 4);
    let skew_threads = env_usize("INTRA_SKEW_THREADS", 1);
    let hot_rows = env_usize("INTRA_SKEW_ROWS", rows.min(400_000)).max(8);
    let base_rows = hot_rows / 8;
    println!(
        "skew arm: world {skew_world} × {skew_threads} workers, \
         rank 0 holds {hot_rows} rows (8× its siblings)"
    );
    let tables: Vec<Table> = (0..skew_world)
        .map(|r| {
            skew_table(
                if r == 0 { hot_rows } else { base_rows },
                100 + r as u64,
            )
        })
        .collect();
    let indices: Vec<Vec<usize>> = tables
        .iter()
        .map(|t| (0..t.num_rows()).rev().collect())
        .collect();
    let skew_pred = Predicate::parse("d0 > 0").unwrap();
    let skew_keys = vec![SortKey::asc("id")];
    #[allow(clippy::type_complexity)]
    let skew_ops: Vec<(
        &str,
        Box<dyn Fn(&Table, &[usize]) -> Table + Sync + '_>,
    )> = vec![
        (
            "gather",
            Box::new(|t, idx| take_parallel(t, idx, exec::current())),
        ),
        ("filter", Box::new(|t, _| select(t, &skew_pred).unwrap())),
        ("sort", Box::new(|t, _| orderby(t, &skew_keys).unwrap())),
    ];
    let mut skew_samples: Vec<(String, f64, f64, u64)> = Vec::new();
    let (mut total_on, mut total_off, mut total_steals) = (0.0f64, 0.0f64, 0u64);
    for (name, op) in &skew_ops {
        let run_mode = |steal: bool| -> (Vec<Table>, f64, u64) {
            let cfg = DistConfig::threads(skew_world)
                .with_intra_op_threads(skew_threads)
                .with_work_steal(steal);
            let cluster = Cluster::new(cfg).expect("skew cluster");
            let run_once = || {
                cluster
                    .run(|ctx| Ok(op(&tables[ctx.rank], &indices[ctx.rank])))
                    .expect("skew run")
            };
            // Untimed first run: warms the pools (a steal signal to a
            // never-spawned sibling pool spawns its thief) and yields
            // the identity-check payload.
            let outs = run_once();
            // Steal gauge per *measured* run, so the JSON number is
            // comparable whatever INTRA_SAMPLES is.
            let stolen_before = cluster.stolen_tasks();
            let stats = measure(opts, || {
                std::hint::black_box(
                    run_once().iter().map(|t| t.num_rows()).sum::<usize>(),
                );
            });
            let runs = (opts.warmup_iters + opts.samples).max(1) as u64;
            let stolen_per_run =
                (cluster.stolen_tasks() - stolen_before) / runs;
            (outs, stats.median, stolen_per_run)
        };
        let (outs_on, on_med, steals) = run_mode(true);
        let (outs_off, off_med, off_steals) = run_mode(false);
        assert_eq!(
            outs_on, outs_off,
            "skew/{name}: stealing changed results"
        );
        assert_eq!(off_steals, 0, "skew/{name}: isolated pools stole");
        let speedup = off_med / on_med.max(1e-12);
        report.add_with(
            &format!("skew_{name}"),
            skew_world as f64,
            on_med,
            vec![
                ("seconds_isolated".to_string(), off_med),
                ("speedup_steal_vs_isolated".to_string(), speedup),
                ("stolen_tasks_per_run".to_string(), steals as f64),
            ],
        );
        println!(
            "  skew_{name}: steal {on_med:>8.4}s  isolated {off_med:>8.4}s \
             ({speedup:.2}x, {steals} tasks stolen/run)"
        );
        skew_samples.push((name.to_string(), on_med, off_med, steals));
        total_on += on_med;
        total_off += off_med;
        total_steals += steals;
    }
    let total_speedup = total_off / total_on.max(1e-12);
    println!(
        "  skew_total: steal {total_on:>8.4}s  isolated {total_off:>8.4}s \
         ({total_speedup:.2}x, {total_steals} tasks stolen/run)"
    );
    skew_samples.push((
        "total".to_string(),
        total_on,
        total_off,
        total_steals,
    ));

    // ---- Fused-pipeline arm: one pass per morsel vs a Table per op ----
    //
    // The same chain (filter → project → hash probe → partial agg) run
    // by the fused executor — every morsel flows through the whole
    // segment in one pass, no intermediate `Table` between stages —
    // and by the operator-at-a-time oracle. The outputs must be
    // bit-identical before either timing counts; the bytes the oracle
    // spends on intermediates are what fusion never allocates.
    use std::collections::HashMap;
    use rylon::pipeline::Pipeline;

    let dim_rows = (rows / 8).max(1);
    let dim_base = gen_table(&DataGenSpec {
        rows: dim_rows,
        payload_cols: 1,
        key_dist: KeyDist::Sequential,
        seed: 9,
    })
    .unwrap();
    let dim = Table::from_columns(vec![
        (
            "id",
            Column::from_i64(
                dim_base.column_by_name("id").unwrap().i64_values().to_vec(),
            ),
        ),
        (
            "w",
            Column::from_f64(
                dim_base.column_by_name("d0").unwrap().f64_values().to_vec(),
            ),
        ),
    ])
    .unwrap();
    let fuse_jopts = JoinOptions::inner("id", "id").with_algo(JoinAlgo::Hash);
    let fuse_pipe = Pipeline::new()
        .select("d0 > 0")
        .unwrap()
        .project(&["id", "d1"])
        .join("dim", fuse_jopts.clone())
        .groupby(GroupByOptions::new(
            &["id"],
            vec![Agg::sum("d1"), Agg::mean("w"), Agg::count("d1")],
        ));
    let mut fuse_env: HashMap<String, Table> = HashMap::new();
    fuse_env.insert("dim".to_string(), dim.clone());
    // Intermediate tables the materialized path allocates and fusion
    // skips (sizes are thread-invariant, so measured once, serially).
    let intermediate_bytes = exec::with_intra_op_threads(1, || {
        let sel = select(&a, &pred).unwrap();
        let proj = rylon::ops::project(&sel, &["id", "d1"]).unwrap();
        let joined = join(&proj, &dim, &fuse_jopts).unwrap();
        sel.byte_size() + proj.byte_size() + joined.byte_size()
    });
    println!(
        "fused-pipeline arm: {rows}×{dim_rows} rows, {:.1} MiB of \
         intermediates fused away",
        intermediate_bytes as f64 / (1024.0 * 1024.0)
    );
    let fuse_reference = exec::with_intra_op_threads(1, || {
        exec::with_pipeline_fuse(false, || {
            fuse_pipe.run_local(&a, &fuse_env).unwrap().0
        })
    });
    let mut fuse_samples: Vec<(usize, f64, f64)> = Vec::new();
    for &t in &threads_sweep {
        let run_mode = |fuse: bool| -> (Table, f64) {
            let out = exec::with_intra_op_threads(t, || {
                exec::with_pipeline_fuse(fuse, || {
                    fuse_pipe.run_local(&a, &fuse_env).unwrap().0
                })
            });
            let stats = exec::with_intra_op_threads(t, || {
                exec::with_pipeline_fuse(fuse, || {
                    measure(opts, || {
                        std::hint::black_box(
                            fuse_pipe
                                .run_local(&a, &fuse_env)
                                .unwrap()
                                .0
                                .num_rows(),
                        );
                    })
                })
            });
            (out, stats.median)
        };
        let (fused_out, fused_med) = run_mode(true);
        let (mat_out, mat_med) = run_mode(false);
        assert_eq!(
            fused_out, fuse_reference,
            "fused pipeline diverged from serial oracle at {t} threads"
        );
        assert_eq!(
            mat_out, fuse_reference,
            "materialized pipeline diverged from serial at {t} threads"
        );
        let speedup = mat_med / fused_med.max(1e-12);
        report.add_with(
            "fused_pipeline",
            t as f64,
            fused_med,
            vec![
                ("seconds_materialized".to_string(), mat_med),
                ("speedup_fused_vs_materialized".to_string(), speedup),
            ],
        );
        let target = if t == 4 { "  [target ≥1.20x]" } else { "" };
        println!(
            "  fused_pipeline t={t}: fused {fused_med:>8.4}s  \
             materialized {mat_med:>8.4}s  ({speedup:.2}x){target}"
        );
        fuse_samples.push((t, fused_med, mat_med));
    }

    // ---- Fault-layer arm: what does the per-rank verdict cost? ----
    //
    // Every collective now carries a trailing Ok/Err verdict byte per
    // rank so any failure aborts symmetrically instead of deadlocking
    // (net::checked). Time an identical storm of small exchanges
    // through the raw fabric and through the checked wrapper; the gap
    // is the whole price of the fault domain on the happy path.
    let fl_world = env_usize("INTRA_FAULT_WORLD", 4);
    let fl_iters = env_usize("INTRA_FAULT_EXCHANGES", 2_000).max(1);
    let fl_payload = 64usize;
    println!(
        "fault-layer arm: world {fl_world}, {fl_iters} exchanges of \
         {fl_payload}B per peer"
    );
    let storm = |fabric: &FabricRef, iters: usize| {
        std::thread::scope(|s| {
            for rank in 0..fl_world {
                let fabric = Arc::clone(fabric);
                s.spawn(move || {
                    for i in 0..iters {
                        let out: Vec<Vec<u8>> = (0..fl_world)
                            .map(|_| vec![(i % 251) as u8; fl_payload])
                            .collect();
                        let got = fabric
                            .exchange(rank, out)
                            .expect("fault-layer exchange");
                        std::hint::black_box(got.len());
                    }
                });
            }
        });
    };
    let time_fabric = |fabric: &FabricRef| -> f64 {
        storm(fabric, 64); // warm the rendezvous path untimed
        measure(opts, || storm(fabric, fl_iters)).median
    };
    let raw: FabricRef = Arc::new(LocalFabric::new(fl_world));
    let checked: FabricRef =
        Arc::new(CheckedFabric::new(Arc::new(LocalFabric::new(fl_world))));
    let raw_med = time_fabric(&raw);
    let checked_med = time_fabric(&checked);
    let per_raw_us = raw_med / fl_iters as f64 * 1e6;
    let per_checked_us = checked_med / fl_iters as f64 * 1e6;
    let overhead_pct = (checked_med / raw_med.max(1e-12) - 1.0) * 100.0;
    report.add_with(
        "fault_layer",
        fl_world as f64,
        checked_med,
        vec![
            ("seconds_raw".to_string(), raw_med),
            ("us_per_exchange_raw".to_string(), per_raw_us),
            ("us_per_exchange_checked".to_string(), per_checked_us),
            ("verdict_overhead_pct".to_string(), overhead_pct),
        ],
    );
    println!(
        "  fault_layer: raw {per_raw_us:>7.2}us/exchange  checked \
         {per_checked_us:>7.2}us/exchange  ({overhead_pct:+.1}% verdict \
         overhead)"
    );

    println!("{}", report.render());
    report.save("intra_op_scaling").expect("save report");

    // Headline JSON tracked in-repo style: BENCH_intra_op.json.
    let json = Json::obj(vec![
        ("rows", Json::num(rows as f64)),
        ("cores", Json::num(cores as f64)),
        (
            "results",
            Json::Arr(
                samples
                    .iter()
                    .map(|(name, t, secs, rps)| {
                        Json::obj(vec![
                            ("op", Json::str(name.clone())),
                            ("threads", Json::num(*t as f64)),
                            ("seconds", Json::num(*secs)),
                            ("rows_per_sec", Json::num(*rps)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "skew",
            Json::obj(vec![
                ("world", Json::num(skew_world as f64)),
                ("intra_op_threads", Json::num(skew_threads as f64)),
                ("hot_rank_rows", Json::num(hot_rows as f64)),
                ("sibling_rows", Json::num(base_rows as f64)),
                (
                    "results",
                    Json::Arr(
                        skew_samples
                            .iter()
                            .map(|(name, on, off, steals)| {
                                Json::obj(vec![
                                    ("op", Json::str(name.clone())),
                                    ("seconds_steal", Json::num(*on)),
                                    (
                                        "seconds_isolated",
                                        Json::num(*off),
                                    ),
                                    (
                                        "speedup_steal_vs_isolated",
                                        Json::num(
                                            *off / on.max(1e-12),
                                        ),
                                    ),
                                    (
                                        "stolen_tasks_per_run",
                                        Json::num(*steals as f64),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "fused_pipeline",
            Json::obj(vec![
                ("fact_rows", Json::num(rows as f64)),
                ("dim_rows", Json::num(dim_rows as f64)),
                (
                    "intermediate_bytes_avoided",
                    Json::num(intermediate_bytes as f64),
                ),
                (
                    "results",
                    Json::Arr(
                        fuse_samples
                            .iter()
                            .map(|(t, fused, mat)| {
                                Json::obj(vec![
                                    ("threads", Json::num(*t as f64)),
                                    ("seconds_fused", Json::num(*fused)),
                                    (
                                        "seconds_materialized",
                                        Json::num(*mat),
                                    ),
                                    (
                                        "speedup_fused_vs_materialized",
                                        Json::num(
                                            *mat / fused.max(1e-12),
                                        ),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "fault_layer",
            Json::obj(vec![
                ("world", Json::num(fl_world as f64)),
                ("exchanges", Json::num(fl_iters as f64)),
                ("payload_bytes", Json::num(fl_payload as f64)),
                ("seconds_raw", Json::num(raw_med)),
                ("seconds_checked", Json::num(checked_med)),
                ("us_per_exchange_raw", Json::num(per_raw_us)),
                ("us_per_exchange_checked", Json::num(per_checked_us)),
                ("verdict_overhead_pct", Json::num(overhead_pct)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_intra_op.json", json.to_string())
        .expect("write BENCH_intra_op.json");
    println!("wrote BENCH_intra_op.json");
}
