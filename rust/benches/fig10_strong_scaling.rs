//! Fig 10 — strong scaling of the distributed inner join (paper §V-1).
//! Fixed total work, parallelism 1→160, four engines, simulated
//! makespan on the calibrated fabric (DESIGN.md §3).
//!
//! Env overrides: FIG10_ROWS (default 2_000_000 — paper used 200M per
//! relation), FIG10_MAX_WORLD, FIG10_SAMPLES.

use rylon::bench_harness::{figures, BenchOpts};
use rylon::net::CostModel;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let rows = env_usize("FIG10_ROWS", 2_000_000);
    let max_world = env_usize("FIG10_MAX_WORLD", 160);
    let samples = env_usize("FIG10_SAMPLES", 3);
    let worlds: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64, 128, 160]
        .into_iter()
        .filter(|&w| w <= max_world)
        .collect();
    let report = figures::fig10(
        rows,
        &worlds,
        &["rylon", "spark_sim", "dask_sim", "modin_sim"],
        BenchOpts {
            warmup_iters: 1,
            samples,
        },
        CostModel::default(),
    )
    .expect("fig10");
    println!("{}", report.render());
    report.save("fig10").expect("save");
    println!("(series saved to bench_out/fig10.json)");
}
