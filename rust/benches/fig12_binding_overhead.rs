//! Fig 12 — binding overhead (paper §V-3): the identical inner join
//! (sort) through the typed core API, the dynamic binding layer, and
//! the PJRT-artifact hot-spot path. The paper's finding — a thin
//! binding over a fast core costs ~nothing — reproduces as three
//! near-coincident curves.
//!
//! Env overrides: FIG12_ROWS (default 2_000_000), FIG12_MAX_WORLD,
//! FIG12_SAMPLES, FIG12_ARTIFACTS (default "artifacts").

use rylon::bench_harness::{figures, BenchOpts};
use rylon::runtime::Runtime;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let rows = env_usize("FIG12_ROWS", 2_000_000);
    let max_world = env_usize("FIG12_MAX_WORLD", 160);
    let samples = env_usize("FIG12_SAMPLES", 3);
    let artifacts = std::env::var("FIG12_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let rt = Runtime::open(&artifacts).ok();
    if rt.is_none() {
        eprintln!(
            "note: no artifacts at '{artifacts}' — pjrt arm falls back to \
             the native kernel (run `make artifacts`)"
        );
    }
    let workers: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64, 128, 160]
        .into_iter()
        .filter(|&w| w <= max_world)
        .collect();
    let report = figures::fig12(
        rows,
        &workers,
        rt.as_ref(),
        BenchOpts {
            warmup_iters: 1,
            samples,
        },
    )
    .expect("fig12");
    println!("{}", report.render());
    // Overhead summary: binding vs core per worker count.
    println!("binding overhead vs core:");
    for &w in &workers {
        let get = |label: &str| {
            report
                .samples
                .iter()
                .find(|s| s.label == label && s.x == w as f64)
                .map(|s| s.seconds)
        };
        if let (Some(core), Some(binding)) = (get("core"), get("binding")) {
            println!(
                "  w={w:>4}: {:+.2}%",
                (binding / core - 1.0) * 100.0
            );
        }
    }
    report.save("fig12").expect("save");
}
