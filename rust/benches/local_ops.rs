//! Per-operator microbenchmarks: every Table I operator on the columnar
//! engine vs the boxed-row engine — the single-node version of the
//! paper's "high performance compute kernels" claim (§II-B/§III).
//!
//! Env overrides: LOCAL_ROWS (default 1_000_000), LOCAL_SAMPLES.

use rylon::baselines::row_engine::RowTable;
use rylon::bench_harness::{measure, BenchOpts, Report};
use rylon::io::datagen::{gen_table, DataGenSpec};
use rylon::ops::groupby::{Agg, GroupByOptions};
use rylon::ops::join::{JoinAlgo, JoinOptions};
use rylon::ops::orderby::SortKey;
use rylon::ops::select::{CmpOp, Predicate};
use rylon::ops::{
    difference, groupby, intersect, join, orderby, project, select, union,
};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let rows = env_usize("LOCAL_ROWS", 1_000_000);
    let opts = BenchOpts {
        warmup_iters: 1,
        samples: env_usize("LOCAL_SAMPLES", 3),
    };
    let a = gen_table(&DataGenSpec::paper_scaling(rows, 1)).unwrap();
    let b = gen_table(&DataGenSpec::paper_scaling(rows, 2)).unwrap();
    let mut report = Report::new(&format!(
        "Local operators, {rows} rows (columnar vs boxed-row where applicable)"
    ));

    // -- Table I operators, columnar engine. -----------------------------
    let pred = Predicate::cmp("d0", CmpOp::Gt, 0.0);
    let s = measure(opts, || {
        std::hint::black_box(select(&a, &pred).unwrap().num_rows());
    });
    report.add("select", rows as f64, s.median);

    let s = measure(opts, || {
        std::hint::black_box(
            project(&a, &["id", "d1"]).unwrap().num_columns(),
        );
    });
    report.add("project", rows as f64, s.median);

    for (name, algo) in [("join_sort", JoinAlgo::Sort), ("join_hash", JoinAlgo::Hash)] {
        let jo = JoinOptions::inner("id", "id").with_algo(algo);
        let s = measure(opts, || {
            std::hint::black_box(join(&a, &b, &jo).unwrap().num_rows());
        });
        report.add(name, rows as f64, s.median);
    }

    let s = measure(opts, || {
        std::hint::black_box(union(&a, &b).unwrap().num_rows());
    });
    report.add("union", rows as f64, s.median);
    let s = measure(opts, || {
        std::hint::black_box(intersect(&a, &b).unwrap().num_rows());
    });
    report.add("intersect", rows as f64, s.median);
    let s = measure(opts, || {
        std::hint::black_box(difference(&a, &b).unwrap().num_rows());
    });
    report.add("difference", rows as f64, s.median);

    let g = GroupByOptions::new(&["id"], vec![Agg::sum("d1")]);
    let s = measure(opts, || {
        std::hint::black_box(groupby(&a, &g).unwrap().num_rows());
    });
    report.add("groupby", rows as f64, s.median);

    let s = measure(opts, || {
        std::hint::black_box(
            orderby(&a, &[SortKey::asc("id")]).unwrap().num_rows(),
        );
    });
    report.add("orderby", rows as f64, s.median);

    // -- Boxed-row comparison on the join (the interpreted-engine cost).
    let small_rows = (rows / 10).max(1);
    let sa = gen_table(&DataGenSpec::paper_scaling(small_rows, 1)).unwrap();
    let sb = gen_table(&DataGenSpec::paper_scaling(small_rows, 2)).unwrap();
    let jo = JoinOptions::inner("id", "id").with_algo(JoinAlgo::Sort);
    let s = measure(opts, || {
        std::hint::black_box(join(&sa, &sb, &jo).unwrap().num_rows());
    });
    report.add("join_columnar_small", small_rows as f64, s.median);
    let ra = RowTable::from_table(&sa);
    let rb = RowTable::from_table(&sb);
    let s = measure(opts, || {
        std::hint::black_box(ra.inner_join(&rb, "id", "id").unwrap().len());
    });
    report.add("join_boxedrow_small", small_rows as f64, s.median);

    println!("{}", report.render());
    // Speed ratio headline.
    let get = |l: &str| {
        report
            .samples
            .iter()
            .find(|s| s.label == l)
            .map(|s| s.seconds)
            .unwrap_or(f64::NAN)
    };
    println!(
        "columnar vs boxed-row join speedup: {:.1}x",
        get("join_boxedrow_small") / get("join_columnar_small")
    );
    report.save("local_ops").expect("save");
}
