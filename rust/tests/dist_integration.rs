//! End-to-end integration over the distributed runtime: CSV → cluster →
//! dist ops → gather, scaling sanity on the sim fabric, failure
//! injection, and the full demo pipeline.

use rylon::column::Column;
use rylon::dist::{dist_join, dist_sort, Cluster, DistConfig};
use rylon::io::csv::{read_csv, write_csv, CsvOptions};
use rylon::io::datagen::{gen_partition, gen_table, DataGenSpec};
use rylon::net::CostModel;
use rylon::ops::join::{join, JoinOptions};
use rylon::ops::orderby::SortKey;
use rylon::table::Table;

#[test]
fn csv_to_dist_join_to_csv() {
    let dir = std::env::temp_dir().join("rylon_it_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let lp = dir.join("left.csv");
    let rp = dir.join("right.csv");
    let l = gen_table(&DataGenSpec::paper_scaling(2000, 11)).unwrap();
    let r = gen_table(&DataGenSpec::paper_scaling(2000, 22)).unwrap();
    write_csv(&l, &lp, &CsvOptions::default()).unwrap();
    write_csv(&r, &rp, &CsvOptions::default()).unwrap();

    // Local reference on the raw tables.
    let expect = join(&l, &r, &JoinOptions::inner("id", "id"))
        .unwrap()
        .num_rows();

    // Distributed: each rank reads the CSVs and slices its block.
    let cluster = Cluster::new(DistConfig::threads(4)).unwrap();
    let outs = cluster
        .run(|ctx| {
            let l = read_csv(&lp, &CsvOptions::default())?;
            let r = read_csv(&rp, &CsvOptions::default())?;
            let slice = |t: &Table| {
                let n = t.num_rows();
                let base = n / ctx.size;
                let extra = n % ctx.size;
                let my = base + (ctx.rank < extra) as usize;
                let off = base * ctx.rank + ctx.rank.min(extra);
                t.slice(off, my)
            };
            dist_join(
                ctx,
                &slice(&l),
                &slice(&r),
                &JoinOptions::inner("id", "id"),
            )
        })
        .unwrap();
    let got: usize = outs.iter().map(|t| t.num_rows()).sum();
    assert_eq!(got, expect);

    // Round-trip the gathered result through CSV.
    let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
    let out_path = dir.join("joined.csv");
    write_csv(&merged, &out_path, &CsvOptions::default()).unwrap();
    let back = read_csv(&out_path, &CsvOptions::default()).unwrap();
    assert_eq!(back.num_rows(), expect);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamed_rank_partitions_match_whole_buffer_ingest() {
    // Every rank streams its block of records out of one shared CSV
    // (bounded-memory reader, tiny chunks so seams land inside quoted
    // newlines and escapes); the reassembled distributed table must be
    // bit-identical to the whole-buffer ingest, and stay usable through
    // a rebalance + join afterwards.
    let dir = std::env::temp_dir().join("rylon_it_stream_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream.csv");
    let n = 3000usize;
    let table = Table::from_columns(vec![
        ("id", Column::from_i64((0..n as i64).map(|i| i % 101).collect())),
        (
            "s",
            Column::from_str(
                &(0..n)
                    .map(|i| match i % 5 {
                        0 => format!("multi\nline,{i}"),
                        1 => format!("esc\"{i}"),
                        2 => format!("日本語{i}"),
                        3 => String::from("x"),
                        _ => format!("plain{i}"),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    write_csv(&table, &path, &CsvOptions::default()).unwrap();
    let whole = read_csv(&path, &CsvOptions::default()).unwrap();
    assert_eq!(whole, table, "sanity: ingest reproduces the table");

    // 512-byte chunks force thousands of seams across the 4 ranks.
    let cfg = DistConfig::threads(4).with_ingest_chunk_bytes(512);
    let cluster = Cluster::new(cfg).unwrap();
    let outs = cluster
        .run(|ctx| {
            rylon::dist::read_csv_partition(
                ctx,
                &path,
                &CsvOptions::default(),
            )
        })
        .unwrap();
    let sizes: Vec<usize> = outs.iter().map(|t| t.num_rows()).collect();
    assert_eq!(sizes, vec![750, 750, 750, 750], "block partition");
    let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
    assert_eq!(merged, whole, "streamed partitions diverged");

    // The streamed partitions feed the normal distributed operators:
    // rebalance (no-op sizes here, but exercises the exchange) then a
    // self-join, checked against the local whole-buffer reference.
    let expect = join(&whole, &whole, &JoinOptions::inner("id", "id"))
        .unwrap()
        .num_rows();
    let outs = cluster
        .run(|ctx| {
            let part = rylon::dist::read_csv_partition(
                ctx,
                &path,
                &CsvOptions::default(),
            )?;
            let balanced = rylon::dist::rebalance(ctx, &part)?;
            dist_join(
                ctx,
                &balanced,
                &balanced,
                &JoinOptions::inner("id", "id"),
            )
        })
        .unwrap();
    let got: usize = outs.iter().map(|t| t.num_rows()).sum();
    assert_eq!(got, expect, "join after streamed ingest diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_pass_ingest_reads_each_byte_once_and_matches_two_pass() {
    use rylon::dist::{read_csv_partition_with, IngestMode, IngestStats};
    // Single-pass must read each file byte exactly once per cluster
    // (the counter is the acceptance gauge), two-pass reads the whole
    // file twice per rank, and the two schemes must produce
    // bit-identical per-rank tables.
    let dir = std::env::temp_dir().join("rylon_it_single_pass");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sp.csv");
    let n = 1200usize;
    let table = Table::from_columns(vec![
        ("id", Column::from_i64((0..n as i64).collect())),
        (
            "s",
            Column::from_str(
                &(0..n)
                    .map(|i| match i % 5 {
                        0 => format!("multi\nline,{i}"),
                        1 => format!("esc\"{i}"),
                        2 => format!("日本語{i}"),
                        3 => format!("crlf\r\npair{i}"),
                        _ => format!("plain{i}"),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    write_csv(&table, &path, &CsvOptions::default()).unwrap();
    let file_len = std::fs::metadata(&path).unwrap().len();
    let whole = read_csv(&path, &CsvOptions::default()).unwrap();

    for world in [1usize, 2, 4] {
        let cfg = DistConfig::threads(world).with_ingest_chunk_bytes(512);
        let cluster = Cluster::new(cfg).unwrap();
        let sp_stats = IngestStats::new();
        let sp = cluster
            .run(|ctx| {
                read_csv_partition_with(
                    ctx,
                    &path,
                    &CsvOptions::default(),
                    IngestMode::SinglePass,
                    Some(&sp_stats),
                )
            })
            .unwrap();
        assert_eq!(
            sp_stats.bytes_read(),
            file_len,
            "world {world}: single-pass must read each byte exactly once"
        );
        let tp_stats = IngestStats::new();
        let tp = cluster
            .run(|ctx| {
                read_csv_partition_with(
                    ctx,
                    &path,
                    &CsvOptions::default(),
                    IngestMode::TwoPass,
                    Some(&tp_stats),
                )
            })
            .unwrap();
        // Two-pass I/O: the count pass streams the whole file on every
        // rank (world × file), but the parse pass stops at the end of
        // each rank's block instead of streaming to EOF — rank r reads
        // about (r+1)/world of the file, i.e. ~file × (world+1)/2
        // cluster-wide, plus chunk-granularity rounding (512-byte
        // chunks here). A lone rank's block is the whole file, so
        // world 1 still reads exactly 2 × file.
        let tp_bytes = tp_stats.bytes_read();
        let count_pass = world as u64 * file_len;
        if world == 1 {
            assert_eq!(tp_bytes, 2 * file_len, "world 1 parses everything");
        } else {
            let parse_bound: u64 = (1..=world as u64)
                .map(|r| r * file_len / world as u64)
                .sum::<u64>()
                + world as u64 * 4 * 512;
            assert!(
                tp_bytes <= count_pass + parse_bound,
                "world {world}: parse pass must stop at block ends \
                 ({tp_bytes} read, bound {})",
                count_pass + parse_bound
            );
            assert!(
                tp_bytes < 2 * world as u64 * file_len,
                "world {world}: two-pass no longer reads the file twice \
                 per rank"
            );
            assert!(
                tp_bytes > count_pass + file_len / 2,
                "world {world}: the tail ranks still stream most of the \
                 file ({tp_bytes} read)"
            );
        }
        assert_eq!(
            sp, tp,
            "world {world}: single-pass diverged from two-pass"
        );
        let merged = Table::concat_all(whole.schema(), &sp).unwrap();
        assert_eq!(merged, whole, "world {world}: reassembly diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_pass_rebalance_elided_for_uniform_rows() {
    use rylon::dist::{read_csv_partition_with, IngestMode, IngestStats};
    use rylon::types::Schema;
    // Fixed-width records with no header: every rank's byte range
    // starts exactly at a record boundary and holds exactly its block
    // of records, so byte ownership *is* the rank-major partition and
    // the post-parse rebalance must move zero rows (and be elided).
    let dir = std::env::temp_dir().join("rylon_it_rebalance_elide");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("uniform.csv");
    let n = 120usize;
    let mut data = String::new();
    for i in 0..n {
        data.push_str(&format!("{:04},abcd\n", i)); // 10 bytes per record
    }
    std::fs::write(&path, &data).unwrap();
    let opts = CsvOptions::default()
        .no_header()
        .with_schema(Schema::parse("a:i64,b:str").unwrap());
    let whole =
        rylon::io::csv::read_csv_from(data.as_bytes(), &opts).unwrap();
    let file_len = std::fs::metadata(&path).unwrap().len();

    for world in [2usize, 3, 4] {
        let cluster = Cluster::new(DistConfig::threads(world)).unwrap();
        let stats = IngestStats::new();
        let outs = cluster
            .run(|ctx| {
                read_csv_partition_with(
                    ctx,
                    &path,
                    &opts,
                    IngestMode::SinglePass,
                    Some(&stats),
                )
            })
            .unwrap();
        assert_eq!(
            stats.rows_moved(),
            0,
            "world {world}: uniform-row file must move zero rows"
        );
        assert_eq!(stats.bytes_read(), file_len);
        let sizes: Vec<usize> = outs.iter().map(|t| t.num_rows()).collect();
        assert!(
            sizes.iter().all(|&s| s == n / world),
            "world {world}: block layout, got {sizes:?}"
        );
        let merged = Table::concat_all(whole.schema(), &outs).unwrap();
        assert_eq!(merged, whole, "world {world}: reassembly diverged");
    }

    // Control: skewed row lengths shift record ownership away from the
    // block partition, so rows must move (and the result still match).
    let path = dir.join("skewed.csv");
    let mut data = String::from("a,b\n");
    for i in 0..200 {
        let s = if i < 30 { "x".repeat(120) } else { "y".to_string() };
        data.push_str(&format!("{i},{s}\n"));
    }
    std::fs::write(&path, &data).unwrap();
    let whole = read_csv(&path, &CsvOptions::default()).unwrap();
    let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
    let stats = IngestStats::new();
    let outs = cluster
        .run(|ctx| {
            read_csv_partition_with(
                ctx,
                &path,
                &CsvOptions::default(),
                IngestMode::SinglePass,
                Some(&stats),
            )
        })
        .unwrap();
    assert!(
        stats.rows_moved() > 0,
        "skewed rows must trigger the rebalance"
    );
    let merged = Table::concat_all(whole.schema(), &outs).unwrap();
    assert_eq!(merged, whole);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_pass_ingest_splices_records_straddling_rank_seams() {
    use rylon::dist::{read_csv_partition_with, IngestMode};
    // One record whose quoted (newline-bearing) field covers most of
    // the file: at world 4 it straddles every rank's byte range, so
    // interior ranks must forward their entire range left as
    // fragments and end up owning zero records before the rebalance.
    let dir = std::env::temp_dir().join("rylon_it_seam_records");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("seam.csv");
    let big = format!("\"x{}\nmid\ny\"", "a".repeat(4000));
    let data = format!("id,s\n1,{big}\n2,plain\n3,\"q,uoted\"\n");
    std::fs::write(&path, &data).unwrap();
    let whole = read_csv(&path, &CsvOptions::default()).unwrap();
    assert_eq!(whole.num_rows(), 3);

    let cluster = Cluster::new(
        DistConfig::threads(4).with_ingest_chunk_bytes(256),
    )
    .unwrap();
    let sp = cluster
        .run(|ctx| {
            read_csv_partition_with(
                ctx,
                &path,
                &CsvOptions::default(),
                IngestMode::SinglePass,
                None,
            )
        })
        .unwrap();
    let tp = cluster
        .run(|ctx| {
            read_csv_partition_with(
                ctx,
                &path,
                &CsvOptions::default(),
                IngestMode::TwoPass,
                None,
            )
        })
        .unwrap();
    assert_eq!(sp, tp, "straddling record broke single/two-pass parity");
    let merged = Table::concat_all(whole.schema(), &sp).unwrap();
    assert_eq!(merged, whole);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_pass_ingest_handles_files_smaller_than_world() {
    // Two data records, four ranks: some ranks own zero bytes and zero
    // records, but still resolve the file's schema and participate in
    // every collective.
    let dir = std::env::temp_dir().join("rylon_it_small_file");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.csv");
    std::fs::write(&path, "id,s\n1,a\n2,b\n").unwrap();
    let whole = read_csv(&path, &CsvOptions::default()).unwrap();

    let cluster = Cluster::new(DistConfig::threads(4)).unwrap();
    let outs = cluster
        .run(|ctx| {
            rylon::dist::read_csv_partition(
                ctx,
                &path,
                &CsvOptions::default(),
            )
        })
        .unwrap();
    let sizes: Vec<usize> = outs.iter().map(|t| t.num_rows()).collect();
    assert_eq!(sizes, vec![1, 1, 0, 0], "block layout with empty ranks");
    for t in &outs {
        assert_eq!(t.schema(), whole.schema(), "empty rank lost the schema");
    }
    let merged = Table::concat_all(whole.schema(), &outs).unwrap();
    assert_eq!(merged, whole);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_pass_ingest_parse_errors_fail_all_ranks_cleanly() {
    use rylon::dist::{read_csv_partition_with, IngestMode};
    // A ragged record in one rank's byte range must abort the whole
    // job (symmetrically — no rank may hang in a later collective),
    // and the cluster must stay serviceable afterwards.
    let dir = std::env::temp_dir().join("rylon_it_sp_errors");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ragged.csv");
    let mut data = String::from("a,b\n");
    for i in 0..200 {
        data.push_str(&format!("{i},{i}\n"));
    }
    data.push_str("oops\n"); // 1 cell, schema has 2
    std::fs::write(&path, &data).unwrap();

    let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
    let r: rylon::Result<Vec<Table>> = cluster.run(|ctx| {
        read_csv_partition_with(
            ctx,
            &path,
            &CsvOptions::default(),
            IngestMode::SinglePass,
            None,
        )
    });
    assert!(r.is_err(), "ragged record must fail the job");
    // The failure poisons the cluster (docs/FAULTS.md); clear it to
    // run the next job.
    assert!(cluster.fault().is_some());
    cluster.clear_fault();
    // Same job again in two-pass mode errors too.
    let r2: rylon::Result<Vec<Table>> = cluster.run(|ctx| {
        read_csv_partition_with(
            ctx,
            &path,
            &CsvOptions::default(),
            IngestMode::TwoPass,
            None,
        )
    });
    assert!(r2.is_err());
    cluster.clear_fault();
    // The fabric and pools survive the aborted jobs.
    let ok = cluster.run(|ctx| Ok(ctx.rank)).unwrap();
    assert_eq!(ok, vec![0, 1, 2]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sim_fabric_strong_scaling_shape() {
    // The Fig 10 sanity core: makespan must drop substantially from 1
    // to 8 ranks (compute-bound region), and the speedup must be
    // sublinear at high rank counts (communication-bound region).
    let rows = 60_000;
    let mk = |p: usize| {
        let cluster =
            Cluster::new(DistConfig::sim(p, CostModel::default())).unwrap();
        cluster
            .run(|ctx| {
                let l = gen_partition(
                    &DataGenSpec::paper_scaling(rows, 1),
                    ctx.rank,
                    ctx.size,
                )?;
                let r = gen_partition(
                    &DataGenSpec::paper_scaling(rows, 2),
                    ctx.rank,
                    ctx.size,
                )?;
                dist_join(ctx, &l, &r, &JoinOptions::inner("id", "id"))
            })
            .unwrap();
        cluster.makespan().unwrap()
    };
    let t1 = mk(1);
    let t8 = mk(8);
    let t64 = mk(64);
    let s8 = t1 / t8;
    let s64 = t1 / t64;
    assert!(s8 > 2.0, "speedup at 8 ranks too low: {s8:.2} (t1={t1:.4})");
    // Communication term keeps 64-rank speedup well below ideal.
    assert!(s64 < 64.0, "impossible superlinear speedup {s64:.2}");
    assert!(
        s64 > s8 * 0.5,
        "64-rank run collapsed entirely: s8={s8:.2} s64={s64:.2}"
    );
}

#[test]
fn dist_sort_then_join_pipeline() {
    // Compose two barrier ops back-to-back on one fabric — exercises
    // generation handling across many exchanges.
    let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
    let outs = cluster
        .run(|ctx| {
            let t = gen_partition(
                &DataGenSpec::paper_scaling(3000, 5),
                ctx.rank,
                ctx.size,
            )?;
            let sorted = dist_sort(ctx, &t, &[SortKey::asc("id")])?;
            let joined = dist_join(
                ctx,
                &sorted,
                &sorted,
                &JoinOptions::inner("id", "id"),
            )?;
            Ok((t.num_rows(), joined.num_rows()))
        })
        .unwrap();
    let rows: usize = outs.iter().map(|(n, _)| n).sum();
    assert_eq!(rows, 3000);
    let joined: usize = outs.iter().map(|(_, j)| j).sum();
    // Self-join cardinality ≥ input rows.
    assert!(joined >= 3000);
}

#[test]
fn rank_failure_fails_whole_job() {
    // A rank erroring *before any collective* aborts the job cleanly.
    let cluster = Cluster::new(DistConfig::threads(3)).unwrap();
    let result: rylon::Result<Vec<()>> = cluster.run(|_ctx| {
        Err(rylon::RylonError::invalid("injected failure"))
    });
    assert!(result.is_err());
}

#[test]
fn mismatched_schema_errors_surface_from_ranks() {
    let cluster = Cluster::new(DistConfig::threads(2)).unwrap();
    let result: rylon::Result<Vec<Table>> = cluster.run(|ctx| {
        let l = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![ctx.rank as i64]),
        )])
        .unwrap();
        // Key column missing on the right: every rank errors identically
        // (before any exchange), so the job aborts without deadlock.
        let r = Table::from_columns(vec![(
            "other",
            Column::from_i64(vec![1]),
        )])
        .unwrap();
        dist_join(ctx, &l, &r, &JoinOptions::inner("k", "k"))
    });
    assert!(result.is_err());
}

#[test]
fn hundred_rank_smoke() {
    // The paper runs up to 400 ranks; sanity-check a 100-rank job on
    // the sim fabric end to end (tiny per-rank data).
    let cluster =
        Cluster::new(DistConfig::sim(100, CostModel::default())).unwrap();
    let outs = cluster
        .run(|ctx| {
            let l = gen_partition(
                &DataGenSpec::paper_scaling(5000, 1),
                ctx.rank,
                ctx.size,
            )?;
            let r = gen_partition(
                &DataGenSpec::paper_scaling(5000, 2),
                ctx.rank,
                ctx.size,
            )?;
            dist_join(ctx, &l, &r, &JoinOptions::inner("id", "id"))
        })
        .unwrap();
    assert_eq!(outs.len(), 100);
    let total: usize = outs.iter().map(|t| t.num_rows()).sum();
    assert!(total > 0);
    assert!(cluster.makespan().unwrap() > 0.0);
}

#[test]
fn demo_pipeline_matches_single_rank() {
    use rylon::ops::groupby::{Agg, GroupByOptions};
    use rylon::pipeline::{Env, Pipeline};
    let build = || {
        Pipeline::new()
            .select("d0 > 0")
            .unwrap()
            .groupby(GroupByOptions::new(
                &["id"],
                vec![Agg::sum("d1"), Agg::count("d1")],
            ))
            .orderby(vec![SortKey::asc("id")])
    };
    let run_with = |world: usize| -> Vec<(i64, i64)> {
        let cluster = Cluster::new(DistConfig::threads(world)).unwrap();
        // One fixed global table, sliced per rank (gen_partition would
        // draw different rows for different world sizes).
        let whole = gen_table(&DataGenSpec::paper_scaling(4000, 77)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let n = whole.num_rows();
                let base = n / ctx.size;
                let extra = n % ctx.size;
                let my = base + (ctx.rank < extra) as usize;
                let off = base * ctx.rank + ctx.rank.min(extra);
                let part = whole.slice(off, my);
                let (out, _) =
                    build().run_dist(ctx, &part, &Env::new())?;
                Ok(out)
            })
            .unwrap();
        let merged = Table::concat_all(outs[0].schema(), &outs).unwrap();
        let mut rows: Vec<(i64, i64)> = (0..merged.num_rows())
            .map(|i| {
                (
                    merged.column(0).value(i).as_i64().unwrap(),
                    merged
                        .column_by_name("count_d1")
                        .unwrap()
                        .value(i)
                        .as_i64()
                        .unwrap(),
                )
            })
            .collect();
        rows.sort();
        rows
    };
    assert_eq!(run_with(1), run_with(5));
}
