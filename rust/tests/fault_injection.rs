//! The fault-domain matrix (`docs/FAULTS.md`): inject `error` / `panic`
//! faults at exact `(rank, exchange)` coordinates under every `dist_*`
//! collective and assert the cluster-wide abort contract —
//!
//! * **symmetry**: every rank's job returns `Err`, and every rank that
//!   observes an attributed abort names the *same* (rank, op, step);
//! * **no deadlocks**: every run is bounded by a collective timeout, so
//!   a stranded rank fails the test instead of hanging it;
//! * **poisoning**: after an abort the cluster fails fast until
//!   `clear_fault`, then runs jobs again;
//! * **transparency**: with no fault plan (or one that never fires) the
//!   checked layer changes nothing — results are bit-identical and the
//!   abort counters stay zero.

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::sync::Once;
use std::time::Duration;

use rylon::exec::{live_spill_dirs, SpillDir};

use rylon::dist::{
    dist_groupby, dist_join, dist_sort, read_csv_partition_with,
    rebalance, shuffle, Cluster, DistConfig, IngestMode, RankCtx,
};
use rylon::io::csv::CsvOptions;
use rylon::io::datagen::{gen_partition, DataGenSpec};
use rylon::net::CostModel;
use rylon::ops::groupby::{Agg, GroupByOptions};
use rylon::ops::join::JoinOptions;
use rylon::ops::orderby::SortKey;

/// Generous deadlock bound: no healthy run here takes seconds, so a
/// rank parked forever fails its test instead of hanging CI.
const TIMEOUT_MS: u64 = 20_000;

/// Silence the default panic-hook backtrace for panics the plan
/// injects on purpose (they are caught and routed into the fault
/// domain); everything else keeps the normal hook.
fn quiet_injected_panics() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&'static str>()
                .copied()
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<String>()
                        .map(String::as_str)
                })
                .unwrap_or("");
            if !msg.starts_with("injected panic") {
                prev(info);
            }
        }));
    });
}

/// One CSV all ingest legs share.
fn csv_fixture(dir_name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(dir_name);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("data.csv");
    let mut data = String::from("id,v\n");
    for i in 0..120 {
        data.push_str(&format!("{i},{}\n", i * 3));
    }
    std::fs::write(&path, data).unwrap();
    path
}

#[derive(Clone, Copy)]
enum Op {
    Shuffle,
    Rebalance,
    Join,
    Sort,
    GroupBy,
    Ingest,
}

const OPS: [(Op, &str); 6] = [
    (Op::Shuffle, "shuffle"),
    (Op::Rebalance, "rebalance"),
    (Op::Join, "dist_join"),
    (Op::Sort, "dist_sort"),
    (Op::GroupBy, "dist_groupby"),
    (Op::Ingest, "ingest"),
];

/// Run one collective-bearing job on this rank.
fn exercise(op: Op, ctx: &mut RankCtx, csv: &Path) -> rylon::Result<()> {
    let spec = DataGenSpec::paper_scaling(240, 7);
    match op {
        Op::Shuffle => {
            let t = gen_partition(&spec, ctx.rank, ctx.size)?;
            shuffle(ctx, &t, &["id".to_string()])?;
        }
        Op::Rebalance => {
            let t = gen_partition(&spec, ctx.rank, ctx.size)?;
            // Skew the partition so rows actually move.
            let t = t.slice(0, if ctx.rank == 0 { t.num_rows() } else { 5 });
            rebalance(ctx, &t)?;
        }
        Op::Join => {
            let l = gen_partition(&spec, ctx.rank, ctx.size)?;
            let r = gen_partition(
                &DataGenSpec::paper_scaling(240, 8),
                ctx.rank,
                ctx.size,
            )?;
            dist_join(ctx, &l, &r, &JoinOptions::inner("id", "id"))?;
        }
        Op::Sort => {
            let t = gen_partition(&spec, ctx.rank, ctx.size)?;
            dist_sort(ctx, &t, &[SortKey::asc("id")])?;
        }
        Op::GroupBy => {
            let t = gen_partition(&spec, ctx.rank, ctx.size)?;
            dist_groupby(
                ctx,
                &t,
                &GroupByOptions::new(&["id"], vec![Agg::sum("d0")]),
            )?;
        }
        Op::Ingest => {
            read_csv_partition_with(
                ctx,
                csv,
                &CsvOptions::default(),
                IngestMode::SinglePass,
                None,
            )?;
        }
    }
    Ok(())
}

/// What one rank observed when its job failed.
#[derive(Clone)]
struct Obs {
    /// `(rank, op, step)` when the error carried abort attribution.
    attr: Option<(usize, String, u64)>,
    msg: String,
}

/// The matrix: `kind` × op × world × rank × injection exchange. Every
/// firing injection must abort every rank with identical attribution;
/// coordinates the job never reaches must leave it untouched.
fn fault_matrix(kind: &str) {
    quiet_injected_panics();
    let csv = csv_fixture(&format!("rylon_fault_matrix_{kind}"));
    for &(op, name) in &OPS {
        for world in [2usize, 4] {
            for inj_rank in [0, world - 1] {
                for exchange in 0..3u64 {
                    let plan = format!("{kind}@{inj_rank}:{exchange}");
                    let label =
                        format!("{name} world={world} plan={plan}");
                    let cluster = Cluster::new(
                        DistConfig::threads(world)
                            .with_intra_op_threads(1)
                            .with_fault_plan(plan.as_str())
                            .with_collective_timeout_ms(TIMEOUT_MS),
                    )
                    .unwrap();
                    let slots: Vec<Mutex<Option<Obs>>> =
                        (0..world).map(|_| Mutex::new(None)).collect();
                    let r = cluster.run(|ctx| {
                        let out = exercise(op, ctx, &csv);
                        if let Err(e) = &out {
                            *slots[ctx.rank].lock().unwrap() = Some(Obs {
                                attr: e.abort_info().map(|i| {
                                    (i.rank, i.op.clone(), i.step)
                                }),
                                msg: e.to_string(),
                            });
                        }
                        out
                    });
                    if cluster.injected_faults() == 0 {
                        // The job finished before reaching the injection
                        // coordinates — it must have run clean.
                        assert!(
                            r.is_ok(),
                            "{label}: plan never fired yet job failed: {}",
                            r.err().map(|e| e.to_string()).unwrap_or_default()
                        );
                        assert_eq!(
                            cluster.aborted_collectives(),
                            0,
                            "{label}: aborts counted without a fault"
                        );
                        continue;
                    }
                    // The injection fired: symmetric, attributed abort.
                    let e = r.expect_err(&format!(
                        "{label}: fault fired but the job succeeded"
                    ));
                    let info = e.abort_info().unwrap_or_else(|| {
                        panic!("{label}: unattributed job error: {e}")
                    });
                    assert_eq!(
                        info.rank, inj_rank,
                        "{label}: wrong rank attributed ({e})"
                    );
                    let observed: Vec<Obs> = slots
                        .iter()
                        .filter_map(|s| s.lock().unwrap().clone())
                        .collect();
                    // A rank may observe the raw injected error (the
                    // injected rank itself, before its wrapper re-wraps
                    // it); everyone else must see the attributed abort.
                    for o in &observed {
                        if o.attr.is_none() {
                            assert!(
                                o.msg.contains("injected"),
                                "{label}: unattributed non-injection \
                                 error: {}",
                                o.msg
                            );
                        }
                    }
                    let attrs: Vec<(usize, String, u64)> = observed
                        .into_iter()
                        .filter_map(|o| o.attr)
                        .collect();
                    for a in &attrs {
                        assert_eq!(
                            a,
                            &attrs[0],
                            "{label}: ranks disagree on attribution"
                        );
                        assert_eq!(a.0, inj_rank, "{label}");
                    }
                    // The fault poisons the cluster: fail fast, then
                    // clear and run again.
                    let fault = cluster
                        .fault()
                        .unwrap_or_else(|| panic!("{label}: not poisoned"));
                    assert_eq!(fault.rank, inj_rank, "{label}");
                    let again: rylon::Result<Vec<()>> =
                        cluster.run(|_| Ok(()));
                    assert!(
                        again.is_err(),
                        "{label}: poisoned cluster ran a job"
                    );
                    assert!(
                        cluster.aborted_collectives() >= 1,
                        "{label}: abort not counted"
                    );
                    cluster.clear_fault();
                    assert!(cluster.fault().is_none(), "{label}");
                    let ok = cluster.run(|ctx| {
                        ctx.allgather(vec![ctx.rank as u8]).map(drop)
                    });
                    assert!(
                        ok.is_ok(),
                        "{label}: cluster unserviceable after clear_fault"
                    );
                }
            }
        }
    }
}

#[test]
fn error_injection_matrix() {
    fault_matrix("error");
}

#[test]
fn panic_injection_matrix() {
    fault_matrix("panic");
}

#[test]
fn fused_segment_fault_aborts_symmetrically_with_innermost_label() {
    // A fault injected mid-pipeline — during the shuffles inside a
    // *fused* select→probe→select segment — must produce the same
    // symmetric, attributed abort as the materialized executor: every
    // rank's job fails, every observed attribution names the injected
    // rank, and the op label is the innermost collective operator
    // ("dist_join"), not a fused-segment pseudo-op.
    use std::collections::HashMap;

    use rylon::ops::join::JoinAlgo;
    use rylon::pipeline::Pipeline;
    use rylon::table::Table;

    quiet_injected_panics();
    let world = 2usize;
    let mut fired = 0u32;
    for kind in ["error", "panic"] {
        for exchange in 0..3u64 {
            let plan = format!("{kind}@1:{exchange}");
            let label = format!("fused pipeline plan={plan}");
            let cluster = Cluster::new(
                DistConfig::threads(world)
                    .with_intra_op_threads(1)
                    .with_fault_plan(plan.as_str())
                    .with_pipeline_fuse(true)
                    .with_collective_timeout_ms(TIMEOUT_MS),
            )
            .unwrap();
            let slots: Vec<Mutex<Option<(usize, String, u64)>>> =
                (0..world).map(|_| Mutex::new(None)).collect();
            let r: rylon::Result<Vec<Table>> = cluster.run(|ctx| {
                let fact = gen_partition(
                    &DataGenSpec::paper_scaling(400, 7),
                    ctx.rank,
                    ctx.size,
                )?;
                let dim = gen_partition(
                    &DataGenSpec::paper_scaling(160, 8),
                    ctx.rank,
                    ctx.size,
                )?;
                let mut env: HashMap<String, Table> = HashMap::new();
                env.insert("dim".to_string(), dim);
                let pipe = Pipeline::new()
                    .select("id >= 0")?
                    .join(
                        "dim",
                        JoinOptions::inner("id", "id")
                            .with_algo(JoinAlgo::Hash),
                    )
                    .select("id >= 0")?;
                let out = pipe.run_dist(ctx, &fact, &env).map(|(t, _)| t);
                if let Err(e) = &out {
                    if let Some(i) = e.abort_info() {
                        *slots[ctx.rank].lock().unwrap() =
                            Some((i.rank, i.op.clone(), i.step));
                    }
                }
                out
            });
            if cluster.injected_faults() == 0 {
                // These coordinates sit past the job's last exchange —
                // it must have run clean.
                assert!(
                    r.is_ok(),
                    "{label}: plan never fired yet the job failed: {}",
                    r.err().map(|e| e.to_string()).unwrap_or_default()
                );
                continue;
            }
            fired += 1;
            let e = r.expect_err(&format!(
                "{label}: fault fired but the job succeeded"
            ));
            let info = e.abort_info().unwrap_or_else(|| {
                panic!("{label}: unattributed job error: {e}")
            });
            assert_eq!(info.rank, 1, "{label}: wrong rank blamed ({e})");
            assert_eq!(
                info.op, "dist_join",
                "{label}: fused segment must attribute the innermost \
                 operator"
            );
            let attrs: Vec<(usize, String, u64)> = slots
                .iter()
                .filter_map(|s| s.lock().unwrap().clone())
                .collect();
            assert!(!attrs.is_empty(), "{label}: no rank saw the abort");
            for a in &attrs {
                assert_eq!(
                    a,
                    &attrs[0],
                    "{label}: ranks disagree on attribution"
                );
                assert_eq!(a.1, "dist_join", "{label}");
            }
            cluster.clear_fault();
        }
    }
    assert!(
        fired > 0,
        "no injection coordinate fired inside the fused segment"
    );
}

#[test]
fn delay_plus_timeout_attributes_the_laggard() {
    // Rank 1 stalls 400 ms before its second exchange; the 60 ms
    // collective timeout must convert rank 0's eternal park into a
    // symmetric abort blaming rank 1.
    let cluster = Cluster::new(
        DistConfig::threads(2)
            .with_intra_op_threads(1)
            .with_fault_plan("delay400@1:1")
            .with_collective_timeout_ms(60),
    )
    .unwrap();
    let r: rylon::Result<Vec<()>> = cluster.run(|ctx| {
        for _ in 0..3 {
            ctx.allgather(vec![ctx.rank as u8])?;
        }
        Ok(())
    });
    let e = r.unwrap_err();
    let info = e.abort_info().expect("attributed timeout");
    assert_eq!(info.rank, 1, "laggard rank blamed: {e}");
    assert!(e.to_string().contains("timed out"), "{e}");
    assert_eq!(cluster.injected_faults(), 1);
    assert!(cluster.aborted_collectives() >= 1);
}

#[test]
fn sim_fabric_joins_the_fault_domain() {
    // Injection and symmetric abort work identically over the BSP
    // simulator fabric.
    let cluster = Cluster::new(
        DistConfig::sim(3, CostModel::default())
            .with_fault_plan("error@2:0")
            .with_collective_timeout_ms(TIMEOUT_MS),
    )
    .unwrap();
    let r: rylon::Result<Vec<()>> =
        cluster.run(|ctx| ctx.allgather(vec![1]).map(drop));
    let e = r.unwrap_err();
    let info = e.abort_info().expect("attributed abort on sim fabric");
    assert_eq!(info.rank, 2);
    assert_eq!(cluster.injected_faults(), 1);
    cluster.clear_fault();
    let ok: rylon::Result<Vec<()>> =
        cluster.run(|ctx| ctx.allgather(vec![2]).map(drop));
    assert!(ok.is_ok(), "sim cluster unserviceable after clear");
}

#[test]
fn no_fault_plan_is_bit_identical_through_the_checked_layer() {
    // The verdict layer is always on; with no firing plan it must be
    // invisible: same results, zero aborts, zero injections. The
    // explicit empty plan also overrides any FAULT_PLAN env default, so
    // this leg is stable under the CI fault matrix.
    let run_sort = |plan: &str| {
        let cluster = Cluster::new(
            DistConfig::threads(3)
                .with_intra_op_threads(1)
                .with_fault_plan(plan)
                .with_collective_timeout_ms(TIMEOUT_MS),
        )
        .unwrap();
        let outs = cluster
            .run(|ctx| {
                let t = gen_partition(
                    &DataGenSpec::paper_scaling(300, 11),
                    ctx.rank,
                    ctx.size,
                )?;
                dist_sort(ctx, &t, &[SortKey::asc("id")])
            })
            .unwrap();
        assert_eq!(cluster.aborted_collectives(), 0);
        assert_eq!(cluster.injected_faults(), 0);
        outs
    };
    let baseline = run_sort("");
    // A plan whose rank is outside the world never fires.
    let shadowed = run_sort("error@7:0");
    assert_eq!(baseline.len(), shadowed.len());
    for (a, b) in baseline.iter().zip(&shadowed) {
        assert_eq!(a, b, "never-firing plan changed results");
    }
}

#[test]
fn bad_fault_plans_are_rejected_at_cluster_build() {
    for bad in ["explode@1:1", "error@x:1", "delay@0:0"] {
        let r = Cluster::new(
            DistConfig::threads(2).with_fault_plan(bad),
        );
        assert!(r.is_err(), "accepted malformed plan '{bad}'");
    }
}

/// Spill-dir leak gate: other tests in this binary may hold their own
/// short-lived spill dirs concurrently (the gauge is process-global),
/// so tolerate churn by waiting for it to drain back to the entry
/// level — a genuine leak never drains.
fn assert_spill_dirs_drain_to(before: usize, label: &str) {
    for _ in 0..200 {
        if live_spill_dirs() <= before {
            return;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!(
        "{label}: {} spill dirs live, {before} at entry — leaked",
        live_spill_dirs()
    );
}

#[test]
fn mid_spill_fault_cleans_every_rank_and_attributes_the_abort() {
    // The out-of-core fault gate (docs/MEMORY.md): a 1-byte budget
    // forces every rank's local join out of core, and each rank holds
    // an explicit spill episode (directory + half-written run file)
    // open across the faulting collective. `error` and `panic` faults
    // injected at the shuffle exchanges must abort every rank
    // symmetrically with the injected rank attributed — and the
    // unwind must delete every rank's spill directory, the explicit
    // one and the operators' own alike.
    quiet_injected_panics();
    let world = 2usize;
    for kind in ["error", "panic"] {
        for exchange in 0..3u64 {
            let plan = format!("{kind}@1:{exchange}");
            let label = format!("mid-spill plan={plan}");
            let before = live_spill_dirs();
            let cluster = Cluster::new(
                DistConfig::threads(world)
                    .with_intra_op_threads(1)
                    .with_memory_budget(1)
                    .with_fault_plan(plan.as_str())
                    .with_collective_timeout_ms(TIMEOUT_MS),
            )
            .unwrap();
            let slots: Vec<Mutex<Option<(usize, String, u64)>>> =
                (0..world).map(|_| Mutex::new(None)).collect();
            let r = cluster.run(|ctx| {
                // A live spill episode spanning the collectives: the
                // abort unwinds through this frame and must remove the
                // directory and its contents on every rank.
                let dir = SpillDir::create()?;
                std::fs::write(dir.file("wip.ryf"), b"half a run")?;
                let l = gen_partition(
                    &DataGenSpec::paper_scaling(600, 7),
                    ctx.rank,
                    ctx.size,
                )?;
                let rt = gen_partition(
                    &DataGenSpec::paper_scaling(600, 8),
                    ctx.rank,
                    ctx.size,
                )?;
                let out =
                    dist_join(ctx, &l, &rt, &JoinOptions::inner("id", "id"));
                if let Err(e) = &out {
                    if let Some(i) = e.abort_info() {
                        *slots[ctx.rank].lock().unwrap() =
                            Some((i.rank, i.op.clone(), i.step));
                    }
                }
                out.map(|t| t.num_rows())
            });
            if cluster.injected_faults() == 0 {
                // Coordinates past the job's last exchange: it must
                // have run clean — and under the 1-byte budget the
                // local joins must actually have gone out of core.
                assert!(
                    r.is_ok(),
                    "{label}: plan never fired yet the job failed: {}",
                    r.err().map(|e| e.to_string()).unwrap_or_default()
                );
                assert!(
                    cluster.spilled_partitions() > 0,
                    "{label}: budget=1 dist_join did not spill"
                );
            } else {
                let e = r.expect_err(&format!(
                    "{label}: fault fired but the job succeeded"
                ));
                let info = e.abort_info().unwrap_or_else(|| {
                    panic!("{label}: unattributed job error: {e}")
                });
                assert_eq!(info.rank, 1, "{label}: wrong rank blamed ({e})");
                let attrs: Vec<(usize, String, u64)> = slots
                    .iter()
                    .filter_map(|s| s.lock().unwrap().clone())
                    .collect();
                for a in &attrs {
                    assert_eq!(
                        a,
                        &attrs[0],
                        "{label}: ranks disagree on attribution"
                    );
                    assert_eq!(a.0, 1, "{label}: wrong rank observed");
                    assert!(
                        a.1 == "shuffle" || a.1 == "dist_join",
                        "{label}: unexpected op blamed: {}",
                        a.1
                    );
                }
            }
            drop(cluster);
            assert_spill_dirs_drain_to(before, &label);
        }
    }
}

fn rylon_cmd(spill_root: &Path, extra: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_rylon"))
        .args(extra)
        // A private spill root per test run: children inherit it, so
        // every rank process spills here and nowhere else.
        .env("RYLON_SPILL_DIR", spill_root)
        .output()
        .expect("spawn rylon binary")
}

fn render(out: &std::process::Output) -> String {
    format!(
        "status: {:?}\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    )
}

fn spill_root_entries(root: &Path) -> Vec<String> {
    std::fs::read_dir(root)
        .map(|rd| {
            rd.filter_map(|e| {
                e.ok().map(|e| e.file_name().to_string_lossy().into_owned())
            })
            .collect()
        })
        .unwrap_or_default()
}

/// Sum every `"bytes_spilled":N` a (possibly multi-rank) stdout
/// printed — each tcp rank process emits its own phase report.
fn total_bytes_spilled(stdout: &str) -> u64 {
    stdout
        .match_indices("\"bytes_spilled\":")
        .map(|(i, pat)| {
            stdout[i + pat.len()..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect::<String>()
                .parse::<u64>()
                .unwrap_or(0)
        })
        .sum()
}

#[test]
fn budgeted_tcp_etl_spills_and_injected_exit_leaks_no_spill_files() {
    // Process-level out-of-core fault gate (docs/MEMORY.md), over the
    // real binary and the tcp fabric. Clean leg: a spill-forcing
    // budget must let the 4-rank ETL complete, book spilled bytes into
    // the phase reports, and leave the private spill root empty.
    // Fault leg: killing rank 1's whole process mid-shuffle must abort
    // the survivors with the dead rank attributed — and still leave
    // the spill root empty on every rank (the survivors' unwinds
    // delete their spill dirs; the dead rank held none at the
    // collective boundary where it was shot).
    let rendezvous = || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        drop(l);
        addr
    };

    let root = std::env::temp_dir().join("rylon_fault_spill_root_clean");
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let rdv = rendezvous();
    let out = rylon_cmd(
        &root,
        &[
            "etl",
            "--rows",
            "2000",
            "--world",
            "4",
            "--fabric",
            "tcp",
            "--rendezvous",
            &rdv,
            "--memory-budget",
            "4096",
            "--collective-timeout",
            "60000",
        ],
    );
    assert!(out.status.success(), "{}", render(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("all 4 ranks completed"),
        "{}",
        render(&out)
    );
    assert!(
        total_bytes_spilled(&stdout) > 0,
        "budget=4096 ETL reported no spilled bytes\n{}",
        render(&out)
    );
    assert_eq!(
        spill_root_entries(&root),
        Vec::<String>::new(),
        "clean run left spill files behind"
    );
    std::fs::remove_dir_all(&root).ok();

    let root = std::env::temp_dir().join("rylon_fault_spill_root_exit");
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let rdv = rendezvous();
    let out = rylon_cmd(
        &root,
        &[
            "etl",
            "--rows",
            "2000",
            "--world",
            "4",
            "--fabric",
            "tcp",
            "--rendezvous",
            &rdv,
            "--memory-budget",
            "4096",
            "--fault-plan",
            "exit@1:3",
            "--collective-timeout",
            "60000",
        ],
    );
    assert!(
        !out.status.success(),
        "job survived a dead rank\n{}",
        render(&out)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("injected exit at rank 1"),
        "exit never fired\n{}",
        render(&out)
    );
    assert!(
        stderr.contains("rank 1 died"),
        "no survivor attributed the dead rank\n{}",
        render(&out)
    );
    assert_eq!(
        spill_root_entries(&root),
        Vec::<String>::new(),
        "aborted run leaked spill files"
    );
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn env_fault_plan_reaches_default_clusters() {
    // Under the CI fault leg (FAULT_PLAN set), a cluster built with no
    // explicit plan inherits the env plan; without the env var the
    // default cluster must be fault-free. Either way: no deadlocks.
    quiet_injected_panics();
    let plan = rylon::exec::default_fault_plan();
    let cluster = Cluster::new(
        DistConfig::threads(2)
            .with_intra_op_threads(1)
            .with_collective_timeout_ms(TIMEOUT_MS),
    )
    .unwrap();
    let r: rylon::Result<Vec<()>> = cluster.run(|ctx| {
        for _ in 0..4 {
            ctx.allgather(vec![ctx.rank as u8])?;
        }
        Ok(())
    });
    if plan.is_empty() || cluster.injected_faults() == 0 {
        assert!(r.is_ok(), "no fault fired yet the job failed");
        assert_eq!(cluster.aborted_collectives(), 0);
    } else {
        let e = r.expect_err("env plan fired but the job succeeded");
        assert!(e.abort_info().is_some(), "unattributed env fault: {e}");
    }
}
