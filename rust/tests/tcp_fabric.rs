//! TCP multi-process fabric, end to end: the dist suite must be
//! bit-identical across fabrics (threads / sim / tcp over real
//! loopback sockets), the bytes metered on the tcp wire must match the
//! in-process oracles, and killing a rank process mid-collective must
//! abort the survivors with the dead rank attributed (the process
//! tests drive the real `rylon` binary in launcher mode).

use std::net::TcpListener;
use std::process::Command;
use std::thread;

use rylon::column::Column;
use rylon::dist::{Cluster, DistConfig, RankCtx};
use rylon::error::Result;
use rylon::io::csv::{write_csv, CsvOptions};
use rylon::io::datagen::{gen_partition, DataGenSpec, KeyDist};
use rylon::net::wire::serialize_table;
use rylon::net::CostModel;
use rylon::ops::groupby::{Agg, GroupByOptions};
use rylon::ops::join::JoinOptions;
use rylon::ops::orderby::SortKey;
use rylon::pipeline::{Env, Pipeline};
use rylon::table::Table;

/// Reserve a loopback rendezvous address: bind port 0, read the
/// assignment, release. The rebind window before the fabric takes the
/// port is tiny; ports are per-test so suites can run concurrently.
fn free_rendezvous() -> String {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

/// The reference distributed workload: the demo ETL shape (filter →
/// repartition join → groupby → global sort), which exercises every
/// collective the dist layer has — allreduce, allgather, and the
/// chunked AllToAll shuffle.
fn workload(ctx: &mut RankCtx) -> Result<Table> {
    let fact = gen_partition(
        &DataGenSpec::paper_scaling(3000, 0xFAC7),
        ctx.rank,
        ctx.size,
    )?;
    let dim = gen_partition(
        &DataGenSpec {
            rows: 300,
            payload_cols: 1,
            key_dist: KeyDist::Sequential,
            seed: 0xD17,
        },
        ctx.rank,
        ctx.size,
    )?;
    let pipeline = Pipeline::new()
        .select("d0 > 0")?
        .join("dim", JoinOptions::inner("id", "id"))
        .groupby(GroupByOptions::new(
            &["id"],
            vec![Agg::sum("d1"), Agg::count("d1"), Agg::mean("d2")],
        ))
        .orderby(vec![SortKey::desc("sum_d1")]);
    let mut env = Env::new();
    env.insert("dim".to_string(), dim);
    let (t, _phases) = pipeline.run_dist(ctx, &fact, &env)?;
    Ok(t)
}

/// One OS-thread-per-rank stand-in for one-process-per-rank: each
/// "process" builds its own [`Cluster`] over a private [`TcpFabric`]
/// and talks to its peers through real loopback sockets only. Returns
/// `(rank, result table, that rank's metered wire bytes)`.
fn run_workload_on_tcp(world: usize) -> Vec<(usize, Table, u64)> {
    let rdv = free_rendezvous();
    thread::scope(|s| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let rdv = rdv.as_str();
                s.spawn(move || {
                    let cluster =
                        Cluster::new(DistConfig::tcp(world, rank, rdv))
                            .unwrap();
                    assert_eq!(cluster.local_ranks(), &[rank]);
                    let mut outs = cluster.run(workload).unwrap();
                    assert_eq!(outs.len(), 1, "tcp hosts one rank");
                    (rank, outs.pop().unwrap(), cluster.bytes_sent())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// The acceptance gate of the fabric: at world 2 and 4, every rank's
/// result on tcp is byte-for-byte the frame the threads fabric
/// produces, and the sum of per-rank tcp wire bytes equals the
/// `bytes_sent` of both in-process oracles (threads and the BSP
/// simulator meter posted bytes identically, so any divergence is a
/// framing bug, not an accounting convention).
#[test]
fn tcp_matches_threads_and_sim_bit_for_bit() {
    for world in [2usize, 4] {
        let threads = Cluster::new(DistConfig::threads(world)).unwrap();
        let expect = threads.run(workload).unwrap();
        let expect_bytes = threads.bytes_sent();
        assert!(expect_bytes > 0, "world {world}: oracle moved no bytes");

        let sim =
            Cluster::new(DistConfig::sim(world, CostModel::default()))
                .unwrap();
        let sim_outs = sim.run(workload).unwrap();
        for (rank, (a, b)) in
            expect.iter().zip(sim_outs.iter()).enumerate()
        {
            assert_eq!(
                serialize_table(a),
                serialize_table(b),
                "world {world} rank {rank}: sim diverged from threads"
            );
        }
        assert_eq!(
            sim.bytes_sent(),
            expect_bytes,
            "world {world}: sim bytes accounting diverged"
        );

        let got = run_workload_on_tcp(world);
        let tcp_bytes: u64 = got.iter().map(|(_, _, b)| *b).sum();
        assert_eq!(
            tcp_bytes, expect_bytes,
            "world {world}: bytes on the tcp wire diverge from the \
             in-process oracle"
        );
        for (rank, t, _) in &got {
            assert_eq!(
                serialize_table(t),
                serialize_table(&expect[*rank]),
                "world {world} rank {rank}: tcp result diverged"
            );
        }
    }
}

/// The single-pass distributed ingest runs its summary-swap protocol
/// steps through `RankCtx::allgather`/`exchange` directly — the one
/// dist path the pipeline workload above does not cross. Each tcp
/// rank process must stream the same partition out of the shared CSV
/// as its threads-fabric twin, seam states and all.
#[test]
fn tcp_single_pass_ingest_matches_threads() {
    let dir = std::env::temp_dir().join("rylon_tcp_ingest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("in.csv");
    let n = 2000usize;
    let table = Table::from_columns(vec![
        (
            "id",
            Column::from_i64((0..n as i64).map(|i| i % 97).collect()),
        ),
        (
            "s",
            Column::from_str(
                &(0..n)
                    .map(|i| match i % 4 {
                        0 => format!("multi\nline,{i}"),
                        1 => format!("esc\"{i}"),
                        2 => format!("日本語{i}"),
                        _ => format!("plain{i}"),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    write_csv(&table, &path, &CsvOptions::default()).unwrap();

    let world = 4usize;
    let threads = Cluster::new(DistConfig::threads(world)).unwrap();
    let expect = threads
        .run(|ctx| {
            rylon::dist::read_csv_partition(
                ctx,
                &path,
                &CsvOptions::default(),
            )
        })
        .unwrap();

    let rdv = free_rendezvous();
    let got: Vec<(usize, Table)> = thread::scope(|s| {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let rdv = rdv.as_str();
                let path = &path;
                s.spawn(move || {
                    let cluster =
                        Cluster::new(DistConfig::tcp(world, rank, rdv))
                            .unwrap();
                    let mut outs = cluster
                        .run(|ctx| {
                            rylon::dist::read_csv_partition(
                                ctx,
                                path,
                                &CsvOptions::default(),
                            )
                        })
                        .unwrap();
                    (rank, outs.pop().unwrap())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (rank, t) in &got {
        assert_eq!(
            serialize_table(t),
            serialize_table(&expect[*rank]),
            "rank {rank}: tcp ingest partition diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Process-level tests: drive the real binary (launcher mode spawns one
// OS process per rank; children inherit the captured stdio, so their
// diagnostics land in the launcher's output).
// ---------------------------------------------------------------------

fn rylon_cmd(extra: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_rylon"))
        .args(extra)
        .output()
        .expect("spawn rylon binary")
}

fn render(out: &std::process::Output) -> String {
    format!(
        "status: {:?}\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    )
}

#[test]
fn launcher_runs_world_4_etl_to_completion() {
    let rdv = free_rendezvous();
    let out = rylon_cmd(&[
        "etl",
        "--rows",
        "2000",
        "--world",
        "4",
        "--fabric",
        "tcp",
        "--rendezvous",
        &rdv,
        "--collective-timeout",
        "60000",
    ]);
    assert!(out.status.success(), "{}", render(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("all 4 ranks completed"),
        "{}",
        render(&out)
    );
}

/// Kill rank 1's whole process mid-shuffle (`exit@1:3` fires inside
/// the join's AllToAll): every survivor must detect the death through
/// the fabric, abort symmetrically, and attribute rank 1 — and the
/// launcher must report the job failed.
#[test]
fn killing_a_rank_mid_shuffle_aborts_survivors_with_attribution() {
    let rdv = free_rendezvous();
    let out = rylon_cmd(&[
        "etl",
        "--rows",
        "2000",
        "--world",
        "4",
        "--fabric",
        "tcp",
        "--rendezvous",
        &rdv,
        "--fault-plan",
        "exit@1:3",
        "--collective-timeout",
        "60000",
    ]);
    assert!(!out.status.success(), "job survived a dead rank\n{}", render(&out));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("injected exit at rank 1"),
        "exit never fired\n{}",
        render(&out)
    );
    // Survivors' abort paths name the dead rank (the lowest — and
    // only — failing rank), not a generic I/O error.
    assert!(
        stderr.contains("rank 1 died"),
        "no survivor attributed the dead rank\n{}",
        render(&out)
    );
    assert!(
        stderr.contains("exited with failure"),
        "launcher did not report failed ranks\n{}",
        render(&out)
    );
}

/// A rank that hangs silently (no death, no frames) must be caught by
/// `--collective-timeout` and blamed by the ranks it starved.
#[test]
fn silent_rank_is_blamed_by_the_collective_timeout() {
    let rdv = free_rendezvous();
    let out = rylon_cmd(&[
        "etl",
        "--rows",
        "1000",
        "--world",
        "2",
        "--fabric",
        "tcp",
        "--rendezvous",
        &rdv,
        "--fault-plan",
        "delay5000@1:1",
        "--collective-timeout",
        "1000",
    ]);
    assert!(!out.status.success(), "hang went unnoticed\n{}", render(&out));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("timed out"),
        "no timeout diagnostic\n{}",
        render(&out)
    );
}
