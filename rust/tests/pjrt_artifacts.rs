//! Integration: the AOT artifacts (built by `make artifacts`) loaded and
//! executed through PJRT must agree with the native Rust kernels —
//! bit-exact for the integer hash path, allclose for the featurizer.
//! This is the L3↔L1 contract that lets the shuffle route rows through
//! either path interchangeably.
//!
//! Skips (with a loud message) when `artifacts/` is absent so `cargo
//! test` still passes on a fresh checkout; `make test` always builds
//! artifacts first.

use rylon::runtime::{FeaturizeKernel, HashKernel, Runtime};
use rylon::util::rng::Xoshiro256;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP pjrt tests (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_kinds() {
    let Some(rt) = runtime() else { return };
    let kinds: std::collections::HashSet<&str> = rt
        .artifacts()
        .iter()
        .map(|a| a.kind.as_str())
        .collect();
    assert!(kinds.contains("hash_partition"));
    assert!(kinds.contains("featurize"));
    // Every artifact's file exists.
    for a in rt.artifacts() {
        assert!(
            std::path::Path::new("artifacts").join(&a.file).exists(),
            "missing {}",
            a.file
        );
    }
}

#[test]
fn hash_kernel_aot_bit_exact_with_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Xoshiro256::new(2024);
    for &nparts in &[4usize, 16] {
        let hk = HashKernel::new(&rt, nparts);
        for &n in &[100usize, 4096, 16384] {
            let keys: Vec<i64> =
                (0..n).map(|_| rng.next_u64() as i64).collect();
            assert!(hk.is_aot(n), "no artifact for n={n} p={nparts}");
            let (pids_a, hist_a) = hk.run(&keys).unwrap();
            let (pids_n, hist_n) =
                HashKernel::native(nparts).run(&keys).unwrap();
            assert_eq!(pids_a, pids_n, "pids n={n} p={nparts}");
            assert_eq!(hist_a, hist_n, "hist n={n} p={nparts}");
            assert_eq!(
                hist_a.iter().sum::<u64>(),
                n as u64,
                "padding leaked into histogram"
            );
        }
    }
}

#[test]
fn hash_kernel_rejects_oversized_batch() {
    let Some(rt) = runtime() else { return };
    let hk = HashKernel::new(&rt, 16);
    let too_big = vec![0i64; 100_000];
    // find() returns no artifact => native fallback works; force the
    // AOT path explicitly to check the capacity guard.
    let meta = rt
        .find("hash_partition", "n", 1, &[("nparts", 16)])
        .unwrap()
        .name
        .clone();
    assert!(hk.run_aot(&rt, &meta, &too_big).is_err());
}

#[test]
fn featurize_aot_allclose_with_native() {
    let Some(rt) = runtime() else { return };
    let fk = FeaturizeKernel::new(&rt);
    let (rows, cols) = (4096usize, 4usize);
    assert!(fk.is_aot(rows, cols));
    let mut rng = Xoshiro256::new(7);
    let x: Vec<f32> = (0..rows * cols)
        .map(|_| (rng.next_normal() * 50.0 - 10.0) as f32)
        .collect();
    let a = fk.run(&x, rows, cols).unwrap();
    let n = FeaturizeKernel::native().run(&x, rows, cols).unwrap();
    let max_abs = a
        .features
        .iter()
        .zip(&n.features)
        .map(|(p, q)| (p - q).abs())
        .fold(0f32, f32::max);
    assert!(max_abs < 1e-3, "max_abs={max_abs}");
    for (ma, mn) in a.mean.iter().zip(&n.mean) {
        assert!((ma - mn).abs() < 1e-2, "mean {ma} vs {mn}");
    }
    // Standardised output: ~zero mean per column.
    for c in 0..cols {
        let m: f32 = (0..rows)
            .map(|r| a.features[r * cols + c])
            .sum::<f32>()
            / rows as f32;
        assert!(m.abs() < 1e-2, "col {c} mean {m}");
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let name = &rt
        .find("hash_partition", "n", 1, &[("nparts", 16)])
        .unwrap()
        .name
        .clone();
    let t0 = std::time::Instant::now();
    let _e1 = rt.executable(name).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _e2 = rt.executable(name).unwrap();
    let second = t1.elapsed();
    assert!(
        second < first / 2,
        "cache did not help: {first:?} -> {second:?}"
    );
}

#[test]
fn shuffle_routing_matches_artifact_routing() {
    // The HashPartitioner used by the real shuffle and the AOT kernel
    // must route identically (the cross-layer routing contract).
    let Some(rt) = runtime() else { return };
    use rylon::dist::{HashPartitioner, Partitioner};
    use rylon::prelude::*;
    let n = 4096usize;
    let mut rng = Xoshiro256::new(99);
    let keys: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
    let t = Table::from_columns(vec![(
        "id",
        Column::from_i64(keys.clone()),
    )])
    .unwrap();
    let p = HashPartitioner::new(&["id".to_string()], 16).unwrap();
    let mut pids = Vec::new();
    p.partition(&t, &mut pids).unwrap();
    let (pids_aot, _) = HashKernel::new(&rt, 16).run(&keys).unwrap();
    assert_eq!(pids, pids_aot);
}
