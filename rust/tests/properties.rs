//! Property-based tests over coordinator invariants (routing, batching,
//! state): randomised inputs from the crate's deterministic RNG, with
//! the failing seed printed — a proptest substitute (proptest is not in
//! the offline registry; every case logs its seed so failures replay).

use std::collections::HashMap;

use rylon::column::Column;
use rylon::dist::{Cluster, DistConfig};
use rylon::net::wire::{deserialize_table, serialize_table};
use rylon::ops::join::{join, JoinAlgo, JoinOptions, JoinType};
use rylon::ops::orderby::{orderby, SortKey};
use rylon::ops::set_ops::{difference, distinct, intersect, subtract, union};
use rylon::table::Table;
use rylon::types::Value;
use rylon::util::rng::Xoshiro256;

const CASES: u64 = 30;

/// Random table: i64 key (with nulls), f64 payload, short string col.
fn random_table(rng: &mut Xoshiro256, max_rows: u64, key_domain: u64) -> Table {
    let n = rng.next_below(max_rows + 1) as usize;
    let keys: Vec<Option<i64>> = (0..n)
        .map(|_| {
            if rng.next_below(12) == 0 {
                None
            } else {
                Some(rng.next_below(key_domain) as i64)
            }
        })
        .collect();
    let vals: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0).collect();
    let strs: Vec<String> = (0..n)
        .map(|_| format!("s{}", rng.next_below(key_domain)))
        .collect();
    Table::from_columns(vec![
        ("k", Column::from_opt_i64(keys)),
        ("v", Column::from_f64(vals)),
        (
            "s",
            Column::from_str(&strs.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
        ),
    ])
    .unwrap()
}

fn row_multiset(t: &Table) -> HashMap<String, usize> {
    let mut m = HashMap::new();
    for i in 0..t.num_rows() {
        let key = t
            .row(i)
            .iter()
            .map(|v| match v {
                Value::Null => "∅".to_string(),
                v => v.render(),
            })
            .collect::<Vec<_>>()
            .join("|");
        *m.entry(key).or_insert(0) += 1;
    }
    m
}

#[test]
fn prop_wire_roundtrip_preserves_tables() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(1000 + seed);
        let t = random_table(&mut rng, 200, 30);
        let back = deserialize_table(&serialize_table(&t))
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(
            row_multiset(&t),
            row_multiset(&back),
            "seed {seed}"
        );
        assert_eq!(t.schema(), back.schema(), "seed {seed}");
    }
}

#[test]
fn prop_join_algorithms_agree_all_types() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(2000 + seed);
        let a = random_table(&mut rng, 80, 15);
        let b = random_table(&mut rng, 80, 15);
        for jt in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::FullOuter,
        ] {
            let opts = JoinOptions::new(jt, &["k"], &["k"]);
            let h = join(&a, &b, &opts.clone().with_algo(JoinAlgo::Hash))
                .unwrap();
            let s = join(&a, &b, &opts.with_algo(JoinAlgo::Sort)).unwrap();
            assert_eq!(
                row_multiset(&h),
                row_multiset(&s),
                "seed {seed} {jt:?}"
            );
        }
    }
}

#[test]
fn prop_inner_join_cardinality_formula() {
    // |A ⋈ B| = Σ_k count_A(k)·count_B(k) over non-null keys.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(3000 + seed);
        let a = random_table(&mut rng, 100, 10);
        let b = random_table(&mut rng, 100, 10);
        let count_by_key = |t: &Table| {
            let mut m: HashMap<i64, usize> = HashMap::new();
            let c = t.column_by_name("k").unwrap();
            for i in 0..t.num_rows() {
                if let Some(k) = c.value(i).as_i64() {
                    *m.entry(k).or_insert(0) += 1;
                }
            }
            m
        };
        let ca = count_by_key(&a);
        let cb = count_by_key(&b);
        let expect: usize = ca
            .iter()
            .map(|(k, na)| na * cb.get(k).copied().unwrap_or(0))
            .sum();
        let j = join(&a, &b, &JoinOptions::inner("k", "k")).unwrap();
        assert_eq!(j.num_rows(), expect, "seed {seed}");
    }
}

#[test]
fn prop_set_op_cardinalities() {
    // Over distinct multisets: |A∪B| = |dA| + |dB| − |A∩B| and
    // |AΔB| = |A∪B| − |A∩B|; A∖B and B∖A partition AΔB.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(4000 + seed);
        let a = random_table(&mut rng, 60, 8);
        let b = random_table(&mut rng, 60, 8);
        let da = distinct(&a).num_rows();
        let db = distinct(&b).num_rows();
        let u = union(&a, &b).unwrap().num_rows();
        let i = intersect(&a, &b).unwrap().num_rows();
        let d = difference(&a, &b).unwrap().num_rows();
        let ab = subtract(&a, &b).unwrap().num_rows();
        let ba = subtract(&b, &a).unwrap().num_rows();
        assert_eq!(u, da + db - i, "seed {seed} union");
        assert_eq!(d, u - i, "seed {seed} difference");
        assert_eq!(d, ab + ba, "seed {seed} partition");
    }
}

#[test]
fn prop_distinct_idempotent_and_subset() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(5000 + seed);
        let t = random_table(&mut rng, 100, 5);
        let d1 = distinct(&t);
        let d2 = distinct(&d1);
        assert_eq!(row_multiset(&d1), row_multiset(&d2), "seed {seed}");
        assert!(d1.num_rows() <= t.num_rows());
        // Every distinct row appears in the original.
        let orig = row_multiset(&t);
        for (row, n) in row_multiset(&d1) {
            assert_eq!(n, 1, "seed {seed} row duplicated");
            assert!(orig.contains_key(&row), "seed {seed} invented row");
        }
    }
}

#[test]
fn prop_orderby_is_sorted_permutation() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(6000 + seed);
        let t = random_table(&mut rng, 150, 20);
        let s = orderby(&t, &[SortKey::asc("k"), SortKey::desc("v")])
            .unwrap();
        assert_eq!(row_multiset(&t), row_multiset(&s), "seed {seed}");
        let kc = s.column_by_name("k").unwrap();
        let vc = s.column_by_name("v").unwrap();
        for i in 1..s.num_rows() {
            let ord = kc.cmp_rows(i - 1, kc, i);
            assert!(ord != std::cmp::Ordering::Greater, "seed {seed}");
            if ord == std::cmp::Ordering::Equal {
                assert!(
                    vc.cmp_rows(i - 1, vc, i)
                        != std::cmp::Ordering::Less,
                    "seed {seed} tiebreak"
                );
            }
        }
    }
}

#[test]
fn prop_shuffle_preserves_multiset_and_routes_consistently() {
    for seed in 0..8 {
        let world = 1 + (seed as usize % 4);
        let cluster = Cluster::new(DistConfig::threads(world)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let mut rng = Xoshiro256::new(
                    7000 + seed * 100 + ctx.rank as u64,
                );
                let t = random_table(&mut rng, 120, 25);
                let shuffled = rylon::dist::shuffle(
                    ctx,
                    &t,
                    &["k".to_string()],
                )?;
                Ok((t, shuffled))
            })
            .unwrap();
        // Global multiset preserved.
        let mut before = HashMap::new();
        let mut after = HashMap::new();
        for (t, s) in &outs {
            for (k, v) in row_multiset(t) {
                *before.entry(k).or_insert(0) += v;
            }
            for (k, v) in row_multiset(s) {
                *after.entry(k).or_insert(0) += v;
            }
        }
        assert_eq!(before, after, "seed {seed} world {world}");
        // Same key never lands on two ranks.
        let mut owner: HashMap<String, usize> = HashMap::new();
        for (rank, (_, s)) in outs.iter().enumerate() {
            let kc = s.column_by_name("k").unwrap();
            for i in 0..s.num_rows() {
                let key = kc.value(i).render();
                if let Some(&prev) = owner.get(&key) {
                    assert_eq!(prev, rank, "key {key} split across ranks");
                } else {
                    owner.insert(key, rank);
                }
            }
        }
    }
}

#[test]
fn prop_rebalance_preserves_order_and_evens_sizes() {
    for seed in 0..8u64 {
        let world = 2 + (seed as usize % 3);
        let cluster = Cluster::new(DistConfig::threads(world)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let mut rng =
                    Xoshiro256::new(8000 + seed * 31 + ctx.rank as u64);
                // Heavily skewed sizes.
                let n = if ctx.rank == 0 {
                    rng.next_below(200) as usize
                } else {
                    rng.next_below(10) as usize
                };
                let start = (ctx.rank * 1_000_000) as i64;
                let t = Table::from_columns(vec![(
                    "v",
                    Column::from_i64(
                        (start..start + n as i64).collect(),
                    ),
                )])
                .unwrap();
                let r = rylon::dist::rebalance(ctx, &t)?;
                Ok((t.num_rows(), r))
            })
            .unwrap();
        let total: usize = outs.iter().map(|(n, _)| n).sum();
        let sizes: Vec<usize> =
            outs.iter().map(|(_, r)| r.num_rows()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), total, "seed {seed}");
        let max = sizes.iter().max().unwrap();
        let min = sizes.iter().min().unwrap();
        assert!(max - min <= 1, "seed {seed}: uneven {sizes:?}");
        // Global order preserved (values increase rank-major).
        let all: Vec<i64> = outs
            .iter()
            .flat_map(|(_, r)| r.column(0).i64_values().to_vec())
            .collect();
        let mut sorted = all.clone();
        sorted.sort();
        assert_eq!(all, sorted, "seed {seed} order broken");
    }
}
