//! Property-based tests over coordinator invariants (routing, batching,
//! state): randomised inputs from the crate's deterministic RNG, with
//! the failing seed printed — a proptest substitute (proptest is not in
//! the offline registry; every case logs its seed so failures replay).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::HashMap;

use rylon::column::Column;
use rylon::dist::{Cluster, DistConfig};
use rylon::exec;
use rylon::io::csv::{
    count_csv_records, read_csv_from, read_csv_records, read_csv_str,
    write_csv_to, CsvOptions,
};
use rylon::io::encode::{
    decode_group, encode_group_with, DecodePruning, Encoding,
};
use rylon::io::ryf::{read_ryf, read_ryf_index, write_ryf};
use rylon::net::wire::{deserialize_table, serialize_table};
use rylon::ops::groupby::{groupby, Agg, GroupByOptions};
use rylon::ops::join::{join, JoinAlgo, JoinOptions, JoinType};
use rylon::ops::orderby::{orderby, SortKey};
use rylon::ops::set_ops::{difference, distinct, intersect, subtract, union};
use rylon::table::Table;
use rylon::types::Value;
use rylon::util::rng::Xoshiro256;

const CASES: u64 = 30;

// ---------------------------------------------------------------------
// Counting allocator: per-thread net/peak byte accounting, so the wire
// mutation property below can assert a corrupt frame never triggers a
// header-sized allocation (the OOM vector the deserializer hardening
// closed). Per-thread cells keep other tests in this binary from
// polluting the measurement window.
// ---------------------------------------------------------------------

struct CountingAlloc;

thread_local! {
    static ALLOC_CUR: Cell<i64> = const { Cell::new(0) };
    static ALLOC_PEAK: Cell<i64> = const { Cell::new(0) };
}

fn track_alloc(delta: i64) {
    // try_with: TLS may be mid-teardown when thread-exit destructors
    // free memory; skipping those events is fine for a peak gauge.
    let _ = ALLOC_CUR.try_with(|cur| {
        let c = cur.get() + delta;
        cur.set(c);
        let _ = ALLOC_PEAK.try_with(|p| {
            if c > p.get() {
                p.set(c);
            }
        });
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            track_alloc(layout.size() as i64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        track_alloc(-(layout.size() as i64));
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            track_alloc(new_size as i64 - layout.size() as i64);
        }
        p
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

/// Run `f`, returning its result and the calling thread's peak net
/// allocation (bytes above the level at entry) during the call.
fn peak_alloc_of<R>(f: impl FnOnce() -> R) -> (usize, R) {
    ALLOC_CUR.with(|c| c.set(0));
    ALLOC_PEAK.with(|p| p.set(0));
    let r = f();
    let peak = ALLOC_PEAK.with(|p| p.get()).max(0) as usize;
    (peak, r)
}

/// Random table: i64 key (with nulls), f64 payload, short string col.
fn random_table(rng: &mut Xoshiro256, max_rows: u64, key_domain: u64) -> Table {
    let n = rng.next_below(max_rows + 1) as usize;
    let keys: Vec<Option<i64>> = (0..n)
        .map(|_| {
            if rng.next_below(12) == 0 {
                None
            } else {
                Some(rng.next_below(key_domain) as i64)
            }
        })
        .collect();
    let vals: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0).collect();
    let strs: Vec<String> = (0..n)
        .map(|_| format!("s{}", rng.next_below(key_domain)))
        .collect();
    Table::from_columns(vec![
        ("k", Column::from_opt_i64(keys)),
        ("v", Column::from_f64(vals)),
        (
            "s",
            Column::from_str(&strs.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
        ),
    ])
    .unwrap()
}

fn row_multiset(t: &Table) -> HashMap<String, usize> {
    let mut m = HashMap::new();
    for i in 0..t.num_rows() {
        let key = t
            .row(i)
            .iter()
            .map(|v| match v {
                Value::Null => "∅".to_string(),
                v => v.render(),
            })
            .collect::<Vec<_>>()
            .join("|");
        *m.entry(key).or_insert(0) += 1;
    }
    m
}

/// One random CSV cell's raw (unencoded) content. `kind` fixes the
/// column's type so schema inference stays stable across the whole
/// column: mixing ints and strings in one column would make rows past
/// the inference window fail to parse (equally in every reader, but
/// the property asserts successful 3-way equality).
fn random_cell(rng: &mut Xoshiro256, kind: u64) -> String {
    if rng.next_below(6) == 0 {
        return String::new(); // null cell
    }
    match kind {
        0 => format!("{}", rng.next_below(2000) as i64 - 1000),
        // Always a decimal point so the column infers f64, not i64.
        1 => format!("{}.5", rng.next_below(1000)),
        2 => match rng.next_below(4) {
            0 => "true".to_string(),
            1 => "false".to_string(),
            2 => "True".to_string(),
            _ => "False".to_string(),
        },
        // Strings always start with a letter so an all-numeric-looking
        // sample can't flip the inferred type; embedded commas, quotes,
        // newlines (bare and CRLF), and multibyte text stress the
        // boundary scan. A `\r` only ever precedes `\n`, so the
        // line-ending `\r`-strip can't eat cell content on rewrite.
        _ => match rng.next_below(8) {
            0 => format!("s,{}", rng.next_below(100)),
            1 => format!("s\"q{}", rng.next_below(100)),
            2 => format!("s\n{}", rng.next_below(100)),
            3 => format!("s\r\nx{}", rng.next_below(100)),
            4 => format!("s日本語{}", rng.next_below(100)),
            _ => format!("s{}", rng.next_below(1000)),
        },
    }
}

/// Append `cell` to `out` with RFC 4180 encoding: quoting is forced
/// when the content requires it and applied gratuitously at random
/// otherwise (a quoted plain field must parse identically).
fn encode_cell(out: &mut String, cell: &str, rng: &mut Xoshiro256) {
    let must_quote =
        cell.contains(',') || cell.contains('"') || cell.contains('\n');
    if must_quote || rng.next_below(4) == 0 {
        out.push('"');
        out.push_str(&cell.replace('"', "\"\""));
        out.push('"');
    } else {
        out.push_str(cell);
    }
}

/// Random RFC 4180 document: random width/height, per-column cell
/// kinds, random gratuitous quoting, LF/CRLF line endings, interspersed
/// blank lines, and random trailing-newline presence.
fn random_csv(rng: &mut Xoshiro256, has_header: bool) -> String {
    let cols = 2 + rng.next_below(4) as usize;
    let kinds: Vec<u64> =
        (0..cols).map(|_| rng.next_below(4)).collect();
    // Headerless empty documents are rejected ("empty csv") — the
    // property wants parses that succeed, so keep one row minimum.
    let min_rows = if has_header { 0 } else { 1 };
    let rows = min_rows + rng.next_below(60) as usize;
    let mut out = String::new();
    if has_header {
        for c in 0..cols {
            if c > 0 {
                out.push(',');
            }
            out.push_str(&format!("c{c}"));
        }
        out.push('\n');
    }
    for r in 0..rows {
        if rng.next_below(8) == 0 {
            out.push('\n'); // blank line, skipped by every reader
        }
        for (c, &kind) in kinds.iter().enumerate() {
            if c > 0 {
                out.push(',');
            }
            let cell = random_cell(rng, kind);
            encode_cell(&mut out, &cell, rng);
        }
        let last = r + 1 == rows;
        match (last, rng.next_below(3)) {
            (true, 0) => {} // no trailing newline
            (_, 1) => out.push_str("\r\n"),
            _ => out.push('\n'),
        }
    }
    out
}

/// The tentpole invariant: streamed parse == whole-buffer parse ==
/// serial parse, at every thread count and at chunk sizes small enough
/// to force many chunk seams (including seams inside quoted fields,
/// escape pairs, CRLF pairs, and multibyte characters).
fn assert_parse_modes_agree(
    text: &str,
    opts: &CsvOptions,
    label: &str,
) -> Table {
    let reference = exec::with_intra_op_threads(1, || {
        read_csv_str(text, opts)
            .unwrap_or_else(|e| panic!("{label}: serial parse failed: {e}"))
    });
    for threads in [1usize, 2, 4, 8] {
        exec::with_intra_op_threads(threads, || {
            exec::with_par_row_threshold(1, || {
                let whole = read_csv_str(text, opts).unwrap();
                assert_eq!(
                    whole, reference,
                    "{label}: whole-buffer diverged at {threads} threads"
                );
                for chunk in [64usize, 257, 8192] {
                    let streamed = exec::with_ingest_chunk_bytes(chunk, || {
                        read_csv_from(text.as_bytes(), opts).unwrap()
                    });
                    assert_eq!(
                        streamed, reference,
                        "{label}: streamed diverged at {threads} \
                         threads, chunk {chunk}"
                    );
                }
            })
        });
    }
    reference
}

#[test]
fn prop_rfc4180_streamed_equals_whole_buffer_equals_serial() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(9000 + seed);
        let has_header = rng.next_below(2) == 0;
        let text = random_csv(&mut rng, has_header);
        let opts = if has_header {
            CsvOptions::default()
        } else {
            CsvOptions::default().no_header()
        };
        assert_parse_modes_agree(&text, &opts, &format!("seed {seed}"));
    }
}

#[test]
fn prop_rfc4180_write_then_reread_roundtrips() {
    // Random tables with quote/comma/newline strings and nulls survive
    // write → re-read in every parse mode (the writer's quoting and the
    // readers' unquoting are inverses).
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(10_000 + seed);
        let n = 1 + rng.next_below(50) as usize;
        let keys: Vec<Option<i64>> = (0..n)
            .map(|_| {
                if rng.next_below(9) == 0 {
                    None
                } else {
                    Some(rng.next_below(1000) as i64 - 500)
                }
            })
            .collect();
        let strs: Vec<String> = (0..n)
            .map(|_| random_cell(&mut rng, 3))
            .collect();
        // Empty string renders as an empty cell, which re-reads as
        // null — keep the roundtrip exact by mapping "" to null here.
        let strs: Vec<Option<String>> = strs
            .into_iter()
            .map(|s| if s.is_empty() { None } else { Some(s) })
            .collect();
        let t = Table::from_columns(vec![
            ("k", Column::from_opt_i64(keys)),
            ("s", Column::from_opt_str(&strs)),
        ])
        .unwrap();
        let mut buf = Vec::new();
        write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let opts = CsvOptions::default()
            .with_schema(t.schema().clone());
        let back =
            assert_parse_modes_agree(&text, &opts, &format!("seed {seed}"));
        assert_eq!(back, t, "seed {seed}: roundtrip changed the table");
    }
}

#[test]
fn prop_partitioned_record_reads_reassemble_the_file() {
    // count + block-ranged streamed reads (the per-rank ingest path)
    // reassemble the whole-buffer parse exactly, for any world size.
    for seed in 0..10u64 {
        let mut rng = Xoshiro256::new(11_000 + seed);
        let text = random_csv(&mut rng, true);
        let opts = CsvOptions::default();
        let whole = read_csv_str(&text, &opts).unwrap();
        exec::with_ingest_chunk_bytes(64, || {
            let total =
                count_csv_records(text.as_bytes(), &opts).unwrap();
            assert_eq!(total, whole.num_rows(), "seed {seed}");
            let world = 1 + (seed as usize % 4);
            let mut parts = Vec::new();
            let mut off = 0usize;
            for r in 0..world {
                let len = total / world
                    + usize::from(r < total % world);
                parts.push(
                    read_csv_records(
                        text.as_bytes(),
                        &opts,
                        off..off + len,
                    )
                    .unwrap(),
                );
                off += len;
            }
            let merged =
                Table::concat_all(whole.schema(), &parts).unwrap();
            assert_eq!(merged, whole, "seed {seed} world {world}");
        });
    }
}

#[test]
fn prop_dist_single_pass_equals_two_pass_equals_whole_buffer() {
    // The PR 4 tentpole invariant: distributed single-pass byte-range
    // ingest == two-pass count-then-parse == whole-buffer parse, per
    // rank and bit for bit, over randomized RFC 4180 documents (quoted
    // newlines, CRLF, escapes, multibyte, blank lines) at several
    // world sizes and ingest chunk sizes — and the single-pass scheme
    // reads each file byte exactly once per cluster.
    use rylon::dist::{read_csv_partition_with, IngestMode, IngestStats};
    for seed in 0..8u64 {
        let mut rng = Xoshiro256::new(12_000 + seed);
        let text = random_csv(&mut rng, true);
        let path = std::env::temp_dir()
            .join(format!("rylon_prop_single_pass_{seed}.csv"));
        std::fs::write(&path, &text).unwrap();
        let whole = read_csv_str(&text, &CsvOptions::default()).unwrap();
        for world in [1usize, 2, 4] {
            for chunk in [64usize, 8192] {
                let cfg = DistConfig::threads(world)
                    .with_ingest_chunk_bytes(chunk);
                let cluster = Cluster::new(cfg).unwrap();
                let stats = IngestStats::new();
                let sp = cluster
                    .run(|ctx| {
                        read_csv_partition_with(
                            ctx,
                            &path,
                            &CsvOptions::default(),
                            IngestMode::SinglePass,
                            Some(&stats),
                        )
                    })
                    .unwrap();
                assert_eq!(
                    stats.bytes_read(),
                    text.len() as u64,
                    "seed {seed} world {world} chunk {chunk}: \
                     single-pass byte count"
                );
                let tp = cluster
                    .run(|ctx| {
                        read_csv_partition_with(
                            ctx,
                            &path,
                            &CsvOptions::default(),
                            IngestMode::TwoPass,
                            None,
                        )
                    })
                    .unwrap();
                assert_eq!(
                    sp, tp,
                    "seed {seed} world {world} chunk {chunk}: \
                     single-pass != two-pass"
                );
                let merged =
                    Table::concat_all(whole.schema(), &sp).unwrap();
                assert_eq!(
                    merged, whole,
                    "seed {seed} world {world} chunk {chunk}: reassembly"
                );
            }
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn prop_wire_roundtrip_preserves_tables() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(1000 + seed);
        let t = random_table(&mut rng, 200, 30);
        let back = deserialize_table(&serialize_table(&t))
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(
            row_multiset(&t),
            row_multiset(&back),
            "seed {seed}"
        );
        assert_eq!(t.schema(), back.schema(), "seed {seed}");
    }
}

#[test]
fn prop_join_algorithms_agree_all_types() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(2000 + seed);
        let a = random_table(&mut rng, 80, 15);
        let b = random_table(&mut rng, 80, 15);
        for jt in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::FullOuter,
        ] {
            let opts = JoinOptions::new(jt, &["k"], &["k"]);
            let h = join(&a, &b, &opts.clone().with_algo(JoinAlgo::Hash))
                .unwrap();
            let s = join(&a, &b, &opts.with_algo(JoinAlgo::Sort)).unwrap();
            assert_eq!(
                row_multiset(&h),
                row_multiset(&s),
                "seed {seed} {jt:?}"
            );
        }
    }
}

#[test]
fn prop_inner_join_cardinality_formula() {
    // |A ⋈ B| = Σ_k count_A(k)·count_B(k) over non-null keys.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(3000 + seed);
        let a = random_table(&mut rng, 100, 10);
        let b = random_table(&mut rng, 100, 10);
        let count_by_key = |t: &Table| {
            let mut m: HashMap<i64, usize> = HashMap::new();
            let c = t.column_by_name("k").unwrap();
            for i in 0..t.num_rows() {
                if let Some(k) = c.value(i).as_i64() {
                    *m.entry(k).or_insert(0) += 1;
                }
            }
            m
        };
        let ca = count_by_key(&a);
        let cb = count_by_key(&b);
        let expect: usize = ca
            .iter()
            .map(|(k, na)| na * cb.get(k).copied().unwrap_or(0))
            .sum();
        let j = join(&a, &b, &JoinOptions::inner("k", "k")).unwrap();
        assert_eq!(j.num_rows(), expect, "seed {seed}");
    }
}

#[test]
fn prop_set_op_cardinalities() {
    // Over distinct multisets: |A∪B| = |dA| + |dB| − |A∩B| and
    // |AΔB| = |A∪B| − |A∩B|; A∖B and B∖A partition AΔB.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(4000 + seed);
        let a = random_table(&mut rng, 60, 8);
        let b = random_table(&mut rng, 60, 8);
        let da = distinct(&a).num_rows();
        let db = distinct(&b).num_rows();
        let u = union(&a, &b).unwrap().num_rows();
        let i = intersect(&a, &b).unwrap().num_rows();
        let d = difference(&a, &b).unwrap().num_rows();
        let ab = subtract(&a, &b).unwrap().num_rows();
        let ba = subtract(&b, &a).unwrap().num_rows();
        assert_eq!(u, da + db - i, "seed {seed} union");
        assert_eq!(d, u - i, "seed {seed} difference");
        assert_eq!(d, ab + ba, "seed {seed} partition");
    }
}

#[test]
fn prop_distinct_idempotent_and_subset() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(5000 + seed);
        let t = random_table(&mut rng, 100, 5);
        let d1 = distinct(&t);
        let d2 = distinct(&d1);
        assert_eq!(row_multiset(&d1), row_multiset(&d2), "seed {seed}");
        assert!(d1.num_rows() <= t.num_rows());
        // Every distinct row appears in the original.
        let orig = row_multiset(&t);
        for (row, n) in row_multiset(&d1) {
            assert_eq!(n, 1, "seed {seed} row duplicated");
            assert!(orig.contains_key(&row), "seed {seed} invented row");
        }
    }
}

#[test]
fn prop_orderby_is_sorted_permutation() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(6000 + seed);
        let t = random_table(&mut rng, 150, 20);
        let s = orderby(&t, &[SortKey::asc("k"), SortKey::desc("v")])
            .unwrap();
        assert_eq!(row_multiset(&t), row_multiset(&s), "seed {seed}");
        let kc = s.column_by_name("k").unwrap();
        let vc = s.column_by_name("v").unwrap();
        for i in 1..s.num_rows() {
            let ord = kc.cmp_rows(i - 1, kc, i);
            assert!(ord != std::cmp::Ordering::Greater, "seed {seed}");
            if ord == std::cmp::Ordering::Equal {
                assert!(
                    vc.cmp_rows(i - 1, vc, i)
                        != std::cmp::Ordering::Less,
                    "seed {seed} tiebreak"
                );
            }
        }
    }
}

#[test]
fn prop_shuffle_preserves_multiset_and_routes_consistently() {
    for seed in 0..8 {
        let world = 1 + (seed as usize % 4);
        let cluster = Cluster::new(DistConfig::threads(world)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let mut rng = Xoshiro256::new(
                    7000 + seed * 100 + ctx.rank as u64,
                );
                let t = random_table(&mut rng, 120, 25);
                let shuffled = rylon::dist::shuffle(
                    ctx,
                    &t,
                    &["k".to_string()],
                )?;
                Ok((t, shuffled))
            })
            .unwrap();
        // Global multiset preserved.
        let mut before = HashMap::new();
        let mut after = HashMap::new();
        for (t, s) in &outs {
            for (k, v) in row_multiset(t) {
                *before.entry(k).or_insert(0) += v;
            }
            for (k, v) in row_multiset(s) {
                *after.entry(k).or_insert(0) += v;
            }
        }
        assert_eq!(before, after, "seed {seed} world {world}");
        // Same key never lands on two ranks.
        let mut owner: HashMap<String, usize> = HashMap::new();
        for (rank, (_, s)) in outs.iter().enumerate() {
            let kc = s.column_by_name("k").unwrap();
            for i in 0..s.num_rows() {
                let key = kc.value(i).render();
                if let Some(&prev) = owner.get(&key) {
                    assert_eq!(prev, rank, "key {key} split across ranks");
                } else {
                    owner.insert(key, rank);
                }
            }
        }
    }
}

#[test]
fn prop_rebalance_preserves_order_and_evens_sizes() {
    for seed in 0..8u64 {
        let world = 2 + (seed as usize % 3);
        let cluster = Cluster::new(DistConfig::threads(world)).unwrap();
        let outs = cluster
            .run(|ctx| {
                let mut rng =
                    Xoshiro256::new(8000 + seed * 31 + ctx.rank as u64);
                // Heavily skewed sizes.
                let n = if ctx.rank == 0 {
                    rng.next_below(200) as usize
                } else {
                    rng.next_below(10) as usize
                };
                let start = (ctx.rank * 1_000_000) as i64;
                let t = Table::from_columns(vec![(
                    "v",
                    Column::from_i64(
                        (start..start + n as i64).collect(),
                    ),
                )])
                .unwrap();
                let r = rylon::dist::rebalance(ctx, &t)?;
                Ok((t.num_rows(), r))
            })
            .unwrap();
        let total: usize = outs.iter().map(|(n, _)| n).sum();
        let sizes: Vec<usize> =
            outs.iter().map(|(_, r)| r.num_rows()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), total, "seed {seed}");
        let max = sizes.iter().max().unwrap();
        let min = sizes.iter().min().unwrap();
        assert!(max - min <= 1, "seed {seed}: uneven {sizes:?}");
        // Global order preserved (values increase rank-major).
        let all: Vec<i64> = outs
            .iter()
            .flat_map(|(_, r)| r.column(0).i64_values().to_vec())
            .collect();
        let mut sorted = all.clone();
        sorted.sort();
        assert_eq!(all, sorted, "seed {seed} order broken");
    }
}

/// Memory-governor property (docs/MEMORY.md): over randomized tables,
/// shrinking the budget from exactly the declared working set (fully
/// admitted — the in-memory path) down to one byte (every reservation
/// denied — recursive spilling) must (1) never change a join / sort /
/// groupby result, (2) never let tracked reservations exceed the
/// budget, (3) never balloon real allocation past a generous multiple
/// of the unbounded path's peak (the counting allocator above is the
/// gauge: out-of-core means bounded *extra* residency, not an O(n²)
/// blowup), and (4) always delete every spill directory on drop.
#[test]
fn prop_shrinking_memory_budget_never_changes_results_or_leaks() {
    for seed in 0..12u64 {
        let mut rng = Xoshiro256::new(14_000 + seed);
        let a = random_table(&mut rng, 300, 12);
        let b = random_table(&mut rng, 150, 12);
        let jopts = JoinOptions::new(JoinType::Left, &["k"], &["k"])
            .with_algo(JoinAlgo::Hash);
        let gopts = GroupByOptions::new(
            &["k"],
            vec![Agg::sum("v"), Agg::count("v"), Agg::mean("v")],
        );
        let skeys = [SortKey::asc("k"), SortKey::desc("s")];

        let check = |label: &str, need: usize, run: &dyn Fn() -> Table| {
            let dirs = exec::live_spill_dirs();
            let (peak0, oracle) = exec::with_intra_op_threads(1, || {
                peak_alloc_of(|| exec::with_memory_budget_bytes(0, run))
            });
            let mut budgets = Vec::new();
            let mut bytes = need.max(1);
            while bytes > 1 {
                budgets.push(bytes);
                bytes /= 4;
            }
            budgets.push(1);
            for budget in budgets {
                exec::reset_reserved_peak();
                let (peak, out) = exec::with_intra_op_threads(1, || {
                    peak_alloc_of(|| {
                        exec::with_memory_budget_bytes(budget, run)
                    })
                });
                assert_eq!(
                    out, oracle,
                    "seed {seed} {label}: budget {budget} changed the \
                     result"
                );
                assert!(
                    exec::reserved_peak() <= budget,
                    "seed {seed} {label}: reserved {} B over the {budget} \
                     B budget",
                    exec::reserved_peak()
                );
                let slack = 4 * peak0 + (1 << 20);
                assert!(
                    peak <= slack,
                    "seed {seed} {label}: budget {budget} peaked at \
                     {peak} B (> {slack} B; unbounded peak {peak0} B)"
                );
                assert_eq!(
                    exec::live_spill_dirs(),
                    dirs,
                    "seed {seed} {label}: budget {budget} leaked a \
                     spill dir"
                );
            }
        };

        // `need` is the working-set estimate each operator declares to
        // the governor, so the first (largest) budget is the admitted
        // boundary case and everything below it spills.
        check("join", a.byte_size() + b.byte_size(), &|| {
            join(&a, &b, &jopts).unwrap()
        });
        check("sort", a.byte_size() + 8 * a.num_rows(), &|| {
            orderby(&a, &skeys).unwrap()
        });
        check("groupby", a.byte_size(), &|| groupby(&a, &gopts).unwrap());
    }
}

/// Wire mutation property: `deserialize_table` over corrupted frames
/// must *fail closed* — every strict truncation is an `Err`, no
/// mutation (bit flip or splice) ever panics, and no outcome allocates
/// more than ~2x the input (a lying header count must not become an
/// OOM). This is the regression net over the `net::wire` hardening.
#[test]
fn prop_wire_mutations_fail_closed() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(13_000 + seed);
        let t = random_table(&mut rng, 120, 20);
        let frame = serialize_table(&t);
        assert!(!frame.is_empty());
        // A well-formed frame parses and stays within budget too.
        let budget = 2 * frame.len() + (16 << 10);
        let (peak, ok) = peak_alloc_of(|| deserialize_table(&frame));
        assert!(ok.is_ok(), "seed {seed}: pristine frame rejected");
        assert!(
            peak <= budget,
            "seed {seed}: clean parse peaked at {peak} B \
             (> {budget} B for a {} B frame)",
            frame.len()
        );

        // Every strict prefix must be an error, never a panic, never
        // a large allocation (truncation removes load-bearing bytes).
        let mut cuts = vec![0, frame.len() - 1, frame.len() / 2];
        cuts.extend(
            (0..8).map(|_| rng.next_below(frame.len() as u64) as usize),
        );
        for cut in cuts {
            let pfx = &frame[..cut];
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    deserialize_table(pfx).map(|t| t.num_rows())
                })
            });
            let r = r.unwrap_or_else(|_| {
                panic!("seed {seed}: truncation at {cut} panicked")
            });
            assert!(
                r.is_err(),
                "seed {seed}: truncation at {cut}/{} parsed",
                frame.len()
            );
            assert!(
                peak <= budget,
                "seed {seed}: truncation at {cut} peaked at {peak} B \
                 (> {budget} B)"
            );
        }

        // Random bit flips: a flip in payload bytes may legitimately
        // still parse (different values), so only `Ok | Err` — never a
        // panic, never an allocation blowup — is asserted.
        for _ in 0..24 {
            let mut m = frame.clone();
            let pos = rng.next_below(m.len() as u64) as usize;
            m[pos] ^= 1u8 << rng.next_below(8);
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    deserialize_table(&m).map(|t| t.num_rows())
                })
            });
            assert!(
                r.is_ok(),
                "seed {seed}: bit flip at byte {pos} panicked"
            );
            assert!(
                peak <= budget,
                "seed {seed}: bit flip at byte {pos} peaked at \
                 {peak} B (> {budget} B)"
            );
        }

        // Random splices (replace a window with junk of a different
        // length): same contract as flips.
        for _ in 0..8 {
            let mut m = frame.clone();
            let at = rng.next_below(m.len() as u64) as usize;
            let end = (at + 1 + rng.next_below(16) as usize).min(m.len());
            let junk: Vec<u8> = (0..rng.next_below(25))
                .map(|_| rng.next_below(256) as u8)
                .collect();
            m.splice(at..end, junk);
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    deserialize_table(&m).map(|t| t.num_rows())
                })
            });
            assert!(
                r.is_ok(),
                "seed {seed}: splice at byte {at} panicked"
            );
            assert!(
                peak <= budget,
                "seed {seed}: splice at byte {at} peaked at {peak} B \
                 (> {budget} B)"
            );
        }
    }
}

/// RYF encoding roundtrip property: every forced per-column encoding
/// (plain, run-length, bit-packed, dictionary) and the auto choice
/// reproduce the exact in-memory table over randomized data — nulls,
/// duplicate strings, multibyte text — and both file formats agree
/// after a full write → read cycle.
#[test]
fn prop_ryf_encodings_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::new(15_000 + seed);
        let t = random_table(&mut rng, 150, 20);
        for force in [
            None,
            Some(Encoding::Plain),
            Some(Encoding::Rle),
            Some(Encoding::BitPack),
            Some(Encoding::Dict),
        ] {
            let buf = encode_group_with(&t, force);
            let (back, pruning) = decode_group(&buf, None)
                .unwrap_or_else(|e| {
                    panic!("seed {seed} force {force:?}: {e}")
                });
            assert_eq!(back, t, "seed {seed} force {force:?}");
            assert_eq!(pruning, DecodePruning::default());
        }
        // Projected decode prunes the middle column and keeps file
        // order, bit-identically to the full decode's columns.
        let buf = encode_group_with(&t, None);
        let proj = vec!["k".to_string(), "s".to_string()];
        let (got, pruning) = decode_group(&buf, Some(&proj)).unwrap();
        assert_eq!(got.num_columns(), 2, "seed {seed}");
        assert_eq!(got.column(0), t.column(0), "seed {seed}");
        assert_eq!(got.column(1), t.column(2), "seed {seed}");
        assert_eq!(pruning.pruned_columns, 1, "seed {seed}");
        // File level: encoded and raw files carry the same table, and
        // the encoded footer has one zone map per group per column.
        let enc = std::env::temp_dir()
            .join(format!("rylon_prop_ryf_enc_{seed}.ryf"));
        let raw = std::env::temp_dir()
            .join(format!("rylon_prop_ryf_raw_{seed}.ryf"));
        exec::with_ryf_encoding(true, || write_ryf(&t, &enc, 32))
            .unwrap();
        exec::with_ryf_encoding(false, || write_ryf(&t, &raw, 32))
            .unwrap();
        assert_eq!(read_ryf(&enc).unwrap(), t, "seed {seed} encoded");
        assert_eq!(read_ryf(&raw).unwrap(), t, "seed {seed} raw");
        let idx = read_ryf_index(&enc).unwrap();
        assert!(idx.encoded, "seed {seed}");
        assert_eq!(idx.stats.len(), idx.metas.len(), "seed {seed}");
        assert!(
            idx.stats.iter().all(|g| g.len() == t.num_columns()),
            "seed {seed}: a group is missing zone maps"
        );
        std::fs::remove_file(&enc).ok();
        std::fs::remove_file(&raw).ok();
    }
}

/// RYF mutation property, in the image of the wire one above: corrupt
/// encoded group bytes and corrupt file headers/footers (metas, zone
/// maps, footer offset) are an `Err` or a well-formed different parse —
/// never a panic, and never an allocation blowup past a small multiple
/// of the pristine parse's peak (a lying group extent is rejected by
/// the index before it can size a read buffer).
#[test]
fn prop_ryf_mutations_fail_closed() {
    for seed in 0..10u64 {
        let mut rng = Xoshiro256::new(16_000 + seed);
        let t = random_table(&mut rng, 120, 20);

        // Group bytes: strict prefixes always fail (the parse is
        // deterministic on a prefix, so it runs dry mid-read or trips
        // the trailing-bytes check); flips and splices never panic.
        let buf = encode_group_with(&t, None);
        let (peak0, ok) = peak_alloc_of(|| decode_group(&buf, None));
        assert!(ok.is_ok(), "seed {seed}: pristine group rejected");
        let budget = 4 * peak0 + (1 << 20);
        let mut cuts = vec![0, buf.len() - 1, buf.len() / 2];
        cuts.extend(
            (0..6).map(|_| rng.next_below(buf.len() as u64) as usize),
        );
        for cut in cuts {
            let pfx = &buf[..cut];
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    decode_group(pfx, None).map(|(t, _)| t.num_rows())
                })
            });
            let r = r.unwrap_or_else(|_| {
                panic!("seed {seed}: group cut at {cut} panicked")
            });
            assert!(r.is_err(), "seed {seed}: group cut at {cut} parsed");
            assert!(
                peak <= budget,
                "seed {seed}: group cut at {cut} peaked at {peak} B \
                 (> {budget} B)"
            );
        }
        for case in 0..24 {
            let mut m = buf.clone();
            if case % 2 == 0 {
                let pos = rng.next_below(m.len() as u64) as usize;
                m[pos] ^= 1u8 << rng.next_below(8);
            } else {
                let at = rng.next_below(m.len() as u64) as usize;
                let end =
                    (at + 1 + rng.next_below(12) as usize).min(m.len());
                let junk: Vec<u8> = (0..rng.next_below(16))
                    .map(|_| rng.next_below(256) as u8)
                    .collect();
                m.splice(at..end, junk);
            }
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    decode_group(&m, None).map(|(t, _)| t.num_rows())
                })
            });
            assert!(
                r.is_ok(),
                "seed {seed} case {case}: mutated group panicked"
            );
            assert!(
                peak <= budget,
                "seed {seed} case {case}: mutated group peaked at \
                 {peak} B (> {budget} B)"
            );
        }

        // File level: truncations kill the read; header and
        // footer/stats flips never panic it.
        let path = std::env::temp_dir()
            .join(format!("rylon_prop_ryf_mut_{seed}.ryf"));
        exec::with_ryf_encoding(true, || write_ryf(&t, &path, 32))
            .unwrap();
        let good = std::fs::read(&path).unwrap();
        let n = good.len();
        let footer_off =
            u64::from_le_bytes(good[n - 8..].try_into().unwrap())
                as usize;
        let (fpeak0, pristine) = peak_alloc_of(|| read_ryf(&path));
        assert_eq!(pristine.unwrap(), t, "seed {seed}");
        let fbudget = 4 * fpeak0 + (1 << 20);
        for cut in [0usize, 7, n / 2, footer_off, n - 9, n - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    read_ryf(&path).map(|t| t.num_rows())
                })
            });
            let r = r.unwrap_or_else(|_| {
                panic!("seed {seed}: file cut at {cut} panicked")
            });
            assert!(r.is_err(), "seed {seed}: file cut at {cut} parsed");
            assert!(
                peak <= fbudget,
                "seed {seed}: file cut at {cut} peaked at {peak} B \
                 (> {fbudget} B)"
            );
        }
        for case in 0..20u64 {
            let mut m = good.clone();
            let pos = if case % 2 == 0 {
                rng.next_below(8) as usize
            } else {
                footer_off
                    + rng.next_below((n - footer_off) as u64) as usize
            };
            m[pos] ^= 1u8 << rng.next_below(8);
            std::fs::write(&path, &m).unwrap();
            let (peak, r) = peak_alloc_of(|| {
                std::panic::catch_unwind(|| {
                    read_ryf(&path).map(|t| t.num_rows())
                })
            });
            assert!(
                r.is_ok(),
                "seed {seed}: flip at byte {pos} panicked the read"
            );
            assert!(
                peak <= fbudget,
                "seed {seed}: flip at byte {pos} peaked at {peak} B \
                 (> {fbudget} B)"
            );
        }
        std::fs::write(&path, &good).unwrap();
        assert_eq!(
            read_ryf(&path).unwrap(),
            t,
            "seed {seed}: pristine bytes must still parse"
        );
        std::fs::remove_file(&path).ok();
    }
}
