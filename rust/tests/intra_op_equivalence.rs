//! Property-style equivalence: every morsel-parallel kernel must
//! produce a **byte-identical** table to the serial kernel — across
//! null-heavy, empty, single-row, and skewed-key inputs, at several
//! thread counts. `Table: PartialEq` compares schemas, values, and
//! validity, and the f64 aggregates fold in the same order on both
//! paths, so `assert_eq!` is the bit-identity check.

use rylon::column::Column;
use rylon::exec;
use rylon::io::datagen::{gen_table, DataGenSpec, KeyDist};
use rylon::ops::groupby::{groupby, Agg, GroupByOptions};
use rylon::ops::join::{join, JoinAlgo, JoinOptions, JoinType};
use rylon::ops::orderby::{orderby, SortKey};
use rylon::ops::select::{select, Predicate};
use rylon::table::Table;
use rylon::util::rng::Xoshiro256;

const THREADS: [usize; 3] = [2, 4, 7];

/// Random table: optional-i64 key, f64 payload, short string column.
fn random_table(seed: u64, rows: usize, key_domain: u64, null_every: u64) -> Table {
    let mut rng = Xoshiro256::new(seed);
    let keys: Vec<Option<i64>> = (0..rows)
        .map(|_| {
            if null_every > 0 && rng.next_below(null_every) == 0 {
                None
            } else {
                Some(rng.next_below(key_domain) as i64)
            }
        })
        .collect();
    let vals: Vec<Option<f64>> = (0..rows)
        .map(|_| {
            if null_every > 0 && rng.next_below(null_every) == 0 {
                None
            } else {
                Some(rng.next_f64() * 200.0 - 100.0)
            }
        })
        .collect();
    let strs: Vec<String> = (0..rows)
        .map(|_| format!("s{}", rng.next_below(key_domain.max(1))))
        .collect();
    Table::from_columns(vec![
        ("k", Column::from_opt_i64(keys)),
        ("v", Column::from_opt_f64(vals)),
        (
            "s",
            Column::from_str(
                &strs.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap()
}

/// The scenario battery the issue calls out: null-heavy, empty,
/// single-row, and skewed-key inputs.
fn scenarios() -> Vec<(&'static str, Table)> {
    let skewed = gen_table(&DataGenSpec {
        rows: 30_000,
        payload_cols: 1,
        key_dist: KeyDist::Zipf {
            domain: 500,
            s: 1.3,
        },
        seed: 1,
    })
    .unwrap();
    // Rename datagen's (id, d0) into the (k, v, s) shape.
    let skewed = Table::from_columns(vec![
        (
            "k",
            Column::from_i64(
                skewed.column_by_name("id").unwrap().i64_values().to_vec(),
            ),
        ),
        (
            "v",
            Column::from_f64(
                skewed.column_by_name("d0").unwrap().f64_values().to_vec(),
            ),
        ),
        (
            "s",
            Column::from_str(
                &skewed
                    .column_by_name("id")
                    .unwrap()
                    .i64_values()
                    .iter()
                    .map(|k| format!("g{}", k % 50))
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    vec![
        ("uniform", random_table(10, 25_000, 800, 0)),
        ("null_heavy", random_table(11, 25_000, 300, 3)),
        ("empty", random_table(12, 0, 10, 2)),
        ("single_row", random_table(13, 1, 10, 0)),
        ("skewed", skewed),
    ]
}

fn assert_equivalent<F: Fn() -> Table>(label: &str, f: F) {
    // An explicit serial budget, so the reference stays serial even
    // under the CI matrix's INTRA_OP_THREADS override.
    let serial = exec::with_intra_op_threads(1, &f);
    for &t in &THREADS {
        let par = exec::with_intra_op_threads(t, &f);
        assert_eq!(par, serial, "{label} diverged at {t} threads");
    }
}

#[test]
fn select_bit_identical() {
    for (name, t) in scenarios() {
        let pred = Predicate::parse("v > -20 and k < 600").unwrap();
        assert_equivalent(&format!("select/{name}"), || {
            select(&t, &pred).unwrap()
        });
        let nullpred = Predicate::parse("v is not null").unwrap();
        assert_equivalent(&format!("select-null/{name}"), || {
            select(&t, &nullpred).unwrap()
        });
    }
}

#[test]
fn hash_join_bit_identical() {
    for (name, l) in scenarios() {
        let r = random_table(99, 12_000, 400, 5);
        for jt in [
            JoinType::Inner,
            JoinType::Left,
            JoinType::Right,
            JoinType::FullOuter,
        ] {
            let opts = JoinOptions::new(jt, &["k"], &["k"])
                .with_algo(JoinAlgo::Hash);
            assert_equivalent(&format!("hash_join/{name}/{jt:?}"), || {
                join(&l, &r, &opts).unwrap()
            });
        }
    }
}

#[test]
fn sort_join_bit_identical() {
    for (name, l) in scenarios() {
        let r = random_table(98, 12_000, 400, 5);
        let opts = JoinOptions::new(JoinType::Inner, &["k"], &["k"])
            .with_algo(JoinAlgo::Sort);
        assert_equivalent(&format!("sort_join/{name}"), || {
            join(&l, &r, &opts).unwrap()
        });
    }
}

#[test]
fn groupby_bit_identical() {
    for (name, t) in scenarios() {
        let opts = GroupByOptions::new(
            &["k"],
            vec![
                Agg::sum("v"),
                Agg::count("v"),
                Agg::mean("v"),
                Agg::min("v"),
                Agg::max("s"),
            ],
        );
        assert_equivalent(&format!("groupby/{name}"), || {
            groupby(&t, &opts).unwrap()
        });
        // Multi-key grouping exercises the combined hash path.
        let multi = GroupByOptions::new(&["k", "s"], vec![Agg::count("v")]);
        assert_equivalent(&format!("groupby-multi/{name}"), || {
            groupby(&t, &multi).unwrap()
        });
    }
}

#[test]
fn orderby_bit_identical() {
    for (name, t) in scenarios() {
        assert_equivalent(&format!("orderby-radix/{name}"), || {
            orderby(&t, &[SortKey::asc("k")]).unwrap()
        });
        assert_equivalent(&format!("orderby-multi/{name}"), || {
            orderby(&t, &[SortKey::desc("s"), SortKey::asc("v")]).unwrap()
        });
    }
}

#[test]
fn skewed_rank_partitions_steal_on_off_serial_identical() {
    // The work-stealing acceptance gate: with one rank holding 0 rows
    // and with one rank holding 90% of all rows, every local kernel
    // must produce bit-identical per-rank results with stealing on,
    // stealing off, and fully serial — at 1/2/4/8 morsel workers per
    // rank. Stealing changes which worker runs a morsel, never where
    // its result lands.
    use rylon::dist::{Cluster, DistConfig};

    let whole = random_table(21, 40_000, 500, 6);
    let dim = random_table(22, 3_000, 400, 5);
    let n = whole.num_rows();

    // Per-rank row counts over 4 ranks (each tiles [0, n) exactly).
    let third = n / 3;
    let hot = n * 9 / 10;
    let rest = n - hot;
    let layouts: Vec<(&str, Vec<usize>)> = vec![
        (
            "zero_row_rank",
            vec![third, 0, third, n - 2 * third],
        ),
        (
            "hot_rank_90pct",
            vec![rest / 3, rest / 3, rest - 2 * (rest / 3), hot],
        ),
    ];

    let pred = Predicate::parse("v > -20 and k < 600").unwrap();
    let jopts = JoinOptions::new(JoinType::Inner, &["k"], &["k"])
        .with_algo(JoinAlgo::Hash);
    let gopts = GroupByOptions::new(
        &["k"],
        vec![Agg::sum("v"), Agg::count("v"), Agg::mean("v")],
    );
    let skeys = vec![SortKey::asc("k"), SortKey::desc("s")];
    let apply = |part: &Table| -> Vec<Table> {
        vec![
            select(part, &pred).unwrap(),
            join(part, &dim, &jopts).unwrap(),
            groupby(part, &gopts).unwrap(),
            orderby(part, &skeys).unwrap(),
        ]
    };

    for (lname, lens) in &layouts {
        assert_eq!(lens.iter().sum::<usize>(), n, "layout must tile");
        let mut off = 0usize;
        let parts: Vec<Table> = lens
            .iter()
            .map(|&len| {
                let p = whole.slice(off, len);
                off += len;
                p
            })
            .collect();
        // Serial reference, computed off-cluster.
        let reference: Vec<Vec<Table>> = parts
            .iter()
            .map(|p| exec::with_intra_op_threads(1, || apply(p)))
            .collect();
        for threads in [1usize, 2, 4, 8] {
            for steal in [true, false] {
                let cfg = DistConfig::threads(4)
                    .with_intra_op_threads(threads)
                    .with_par_row_threshold(64)
                    .with_work_steal(steal);
                let cluster = Cluster::new(cfg).unwrap();
                assert_eq!(cluster.work_steal(), steal);
                let outs = cluster
                    .run(|ctx| Ok(apply(&parts[ctx.rank])))
                    .unwrap();
                assert_eq!(
                    outs, reference,
                    "{lname} diverged at {threads} threads, steal={steal}"
                );
                if !steal {
                    assert_eq!(
                        cluster.stolen_tasks(),
                        0,
                        "isolated pools must never steal"
                    );
                }
            }
        }
    }
}

#[test]
fn gather_nullable_string_bit_identical() {
    use rylon::compute::filter::{take_column_parallel, take_parallel};
    use rylon::exec::ExecContext;

    let n = 20_000usize;
    let mut rng = Xoshiro256::new(77);
    let columns: Vec<(&str, Column)> = vec![
        (
            "null_heavy_i64",
            Column::from_opt_i64(
                (0..n)
                    .map(|i| if i % 3 == 0 { None } else { Some(i as i64) })
                    .collect(),
            ),
        ),
        (
            "null_heavy_f64",
            Column::from_opt_f64(
                (0..n)
                    .map(|i| {
                        if i % 5 == 0 {
                            None
                        } else {
                            Some(i as f64 * 0.25 - 100.0)
                        }
                    })
                    .collect(),
            ),
        ),
        ("all_null", Column::from_opt_i64(vec![None; n])),
        (
            "opt_bool",
            Column::from_opt_bool(
                (0..n)
                    .map(|i| match i % 4 {
                        0 => None,
                        1 => Some(true),
                        _ => Some(false),
                    })
                    .collect(),
            ),
        ),
        (
            "opt_str",
            Column::from_opt_str(
                &(0..n)
                    .map(|i| match i % 6 {
                        0 => None,
                        1 => Some(String::new()), // empty string ≠ null
                        2 => Some(format!("日本語-{i}")),
                        _ => Some(format!("value-{i}")),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
        (
            "dense_str",
            Column::from_str(
                &(0..n).map(|i| format!("s{i}")).collect::<Vec<_>>(),
            ),
        ),
    ];
    let index_sets: Vec<(&str, Vec<usize>)> = vec![
        (
            "reversed_even",
            (0..n).rev().filter(|i| i % 2 == 0).collect(),
        ),
        (
            "random_repeats",
            (0..n)
                .map(|_| rng.next_below(n as u64) as usize)
                .collect(),
        ),
        ("dense_prefix", (0..n / 2).collect()),
    ];
    for (cname, col) in &columns {
        for (iname, indices) in &index_sets {
            let serial = col.take(indices);
            for threads in [1usize, 2, 4, 8] {
                let par = take_column_parallel(
                    col,
                    indices,
                    ExecContext::new(threads),
                );
                assert_eq!(
                    par, serial,
                    "gather {cname}/{iname} diverged at {threads} threads"
                );
            }
        }
    }
    // Whole-table parallel take over the same column mix.
    let table = Table::from_columns(columns).unwrap();
    let indices: Vec<usize> = (0..n).rev().filter(|i| i % 3 != 1).collect();
    let serial = table.take(&indices);
    for threads in [1usize, 2, 4, 8] {
        let par = take_parallel(&table, &indices, ExecContext::new(threads));
        assert_eq!(par, serial, "table take diverged at {threads} threads");
    }
    // Small inputs with the threshold knob forced down still match.
    exec::with_par_row_threshold(1, || {
        let small: Vec<usize> = vec![3, 1, 2, 1, 0, 4, 4];
        for (cname, col) in
            [("opt", Column::from_opt_i64(vec![Some(1), None, Some(3), None, Some(5)])),
             ("str", Column::from_opt_str(&[Some("a"), None, Some(""), Some("日本"), Some("e")]))]
        {
            let serial = col.take(&small);
            let par =
                take_column_parallel(&col, &small, ExecContext::new(4));
            assert_eq!(par, serial, "forced small gather diverged ({cname})");
        }
    });
}

#[test]
fn csv_parse_parallel_vs_serial_roundtrip() {
    use rylon::io::csv::{read_csv_str, write_csv_to, CsvOptions};
    use rylon::types::Schema;

    // Quoted / multibyte / ragged-null fixture, written by our own
    // writer so quoting is exercised on both sides.
    let n = 8_000usize;
    let t = Table::from_columns(vec![
        (
            "k",
            Column::from_opt_i64(
                (0..n)
                    .map(|i| {
                        if i % 7 == 0 {
                            None
                        } else {
                            Some(i as i64 % 97)
                        }
                    })
                    .collect(),
            ),
        ),
        (
            "v",
            Column::from_opt_f64(
                (0..n)
                    .map(|i| {
                        if i % 11 == 0 {
                            None
                        } else {
                            Some(i as f64 * 0.5 - 1.25)
                        }
                    })
                    .collect(),
            ),
        ),
        (
            "s",
            Column::from_str(
                &(0..n)
                    .map(|i| match i % 5 {
                        0 => format!("comma,{i}"),
                        1 => format!("quote\"{i}"),
                        2 => format!("日本語{i}"),
                        3 => format!("line\nbreak{i}"),
                        _ => format!("plain{i}"),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    let mut buf = Vec::new();
    write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
    let csv = String::from_utf8(buf).unwrap();
    let opts = CsvOptions::default()
        .with_schema(Schema::parse("k:i64,v:f64,s:str").unwrap());
    let serial = exec::with_intra_op_threads(1, || {
        read_csv_str(&csv, &opts).unwrap()
    });
    assert_eq!(serial, t, "csv roundtrip must reproduce the table");
    for threads in [1usize, 2, 4, 8] {
        let par = exec::with_intra_op_threads(threads, || {
            read_csv_str(&csv, &opts).unwrap()
        });
        assert_eq!(par, serial, "csv parse diverged at {threads} threads");
    }
    // Inferred schema (no explicit types) must also be thread-invariant.
    let serial_inferred = exec::with_intra_op_threads(1, || {
        read_csv_str(&csv, &CsvOptions::default()).unwrap()
    });
    for threads in [2usize, 4, 8] {
        let par = exec::with_intra_op_threads(threads, || {
            read_csv_str(&csv, &CsvOptions::default()).unwrap()
        });
        assert_eq!(
            par, serial_inferred,
            "inferred csv parse diverged at {threads} threads"
        );
    }
}

#[test]
fn csv_streamed_ingest_matches_whole_buffer_at_all_threads_and_chunks() {
    use rylon::io::csv::{read_csv_from, read_csv_str, write_csv_to, CsvOptions};

    // Same adversarial shape as the whole-buffer roundtrip above —
    // quoted commas/newlines, escapes, multibyte — but parsed through
    // the streaming reader with chunk sizes that put seams inside every
    // construct, at every thread count (speculative parallel boundary
    // scan engaged via the forced-down row threshold).
    let n = 4_000usize;
    let t = Table::from_columns(vec![
        ("k", Column::from_i64((0..n as i64).collect())),
        (
            "s",
            Column::from_str(
                &(0..n)
                    .map(|i| match i % 5 {
                        0 => format!("comma,{i}"),
                        1 => format!("quote\"{i}"),
                        2 => format!("日本語{i}"),
                        3 => format!("line\nbreak{i}"),
                        _ => format!("plain{i}"),
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    let mut buf = Vec::new();
    write_csv_to(&t, &mut buf, &CsvOptions::default()).unwrap();
    let csv = String::from_utf8(buf).unwrap();
    let reference = exec::with_intra_op_threads(1, || {
        read_csv_str(&csv, &CsvOptions::default()).unwrap()
    });
    for threads in [1usize, 2, 4, 8] {
        for chunk in [64usize, 4096, 1 << 22] {
            let streamed = exec::with_intra_op_threads(threads, || {
                exec::with_par_row_threshold(1, || {
                    exec::with_ingest_chunk_bytes(chunk, || {
                        read_csv_from(
                            csv.as_bytes(),
                            &CsvOptions::default(),
                        )
                        .unwrap()
                    })
                })
            });
            assert_eq!(
                streamed, reference,
                "streamed ingest diverged at {threads} threads, \
                 chunk {chunk}"
            );
        }
    }
}

#[test]
fn ryf_read_parallel_vs_serial_roundtrip() {
    use rylon::io::ryf::{read_ryf, read_ryf_partition, write_ryf};

    let n = 10_000usize;
    let t = Table::from_columns(vec![
        ("id", Column::from_i64((0..n as i64).collect())),
        (
            "s",
            Column::from_opt_str(
                &(0..n)
                    .map(|i| {
                        if i % 9 == 0 {
                            None
                        } else {
                            Some(format!("行{i}"))
                        }
                    })
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    let path =
        std::env::temp_dir().join("rylon_intra_op_equivalence_ingest.ryf");
    write_ryf(&t, &path, 512).unwrap(); // 20 row groups
    let serial =
        exec::with_intra_op_threads(1, || read_ryf(&path).unwrap());
    assert_eq!(serial, t);
    let part_serial = exec::with_intra_op_threads(1, || {
        read_ryf_partition(&path, 2, 3).unwrap()
    });
    for threads in [1usize, 2, 4, 8] {
        exec::with_intra_op_threads(threads, || {
            assert_eq!(
                read_ryf(&path).unwrap(),
                serial,
                "ryf read diverged at {threads} threads"
            );
            assert_eq!(
                read_ryf_partition(&path, 2, 3).unwrap(),
                part_serial,
                "ryf partition read diverged at {threads} threads"
            );
        });
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn build_parallel_chains_identical_buckets() {
    use rylon::compute::hash::{hash_columns, HashChains};
    let t = random_table(55, 40_000, 123, 4);
    let cols = vec![t.column_by_name("k").unwrap()];
    let mut hashes = Vec::new();
    hash_columns(&cols, t.num_rows(), &mut hashes);
    let skip = |i: usize| !t.column_by_name("k").unwrap().is_valid(i);
    let serial = HashChains::build(&hashes, skip);
    for &threads in &THREADS {
        let par = HashChains::build_parallel(
            &hashes,
            skip,
            exec::ExecContext::new(threads),
        );
        for &h in hashes.iter().take(2000) {
            assert_eq!(
                serial.bucket(h).collect::<Vec<_>>(),
                par.bucket(h).collect::<Vec<_>>(),
                "bucket {h:#x} at {threads} threads"
            );
        }
    }
}

#[test]
fn fused_pipeline_bit_identical_matrix() {
    // The fused-executor acceptance gate: every fusable stage chain
    // must produce a **bit-identical** table — and the same `rows_out`
    // total — whether the chain runs operator-at-a-time (each stage
    // materialises a `Table`) or as fused morsel segments (one pass
    // per morsel, no intermediates). The matrix crosses chains ×
    // 1/2/4/8 morsel workers × steal on/off × batch_rows, so fusion
    // is checked against every scheduler the executor has.
    use std::collections::HashMap;
    use rylon::pipeline::Pipeline;

    let fact = random_table(31, 30_000, 600, 6);
    let mut rng = Xoshiro256::new(32);
    let dim_rows = 2_000usize;
    let dkeys: Vec<i64> =
        (0..dim_rows).map(|_| rng.next_below(500) as i64).collect();
    let dim = Table::from_columns(vec![
        ("k", Column::from_i64(dkeys.clone())),
        (
            "w",
            Column::from_f64(
                (0..dim_rows).map(|_| rng.next_f64() * 10.0).collect(),
            ),
        ),
        (
            "name",
            Column::from_str(
                &dkeys
                    .iter()
                    .map(|k| format!("n{}", k % 20))
                    .collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap();
    let mut env: HashMap<String, Table> = HashMap::new();
    env.insert("dim".to_string(), dim);

    let inner = || {
        JoinOptions::new(JoinType::Inner, &["k"], &["k"])
            .with_algo(JoinAlgo::Hash)
    };
    let left = || {
        JoinOptions::new(JoinType::Left, &["k"], &["k"])
            .with_algo(JoinAlgo::Hash)
    };
    let aggs = || {
        vec![Agg::sum("v"), Agg::count("v"), Agg::mean("w")]
    };
    // Each chain ends a different way through the planner: pure
    // streamable run, probe-terminated segment, left-join fold with a
    // nullable probe side, the full select→project→probe→select→
    // partial-agg pass, and a breaker (orderby) splitting two fused
    // segments.
    let chains: Vec<(&str, Box<dyn Fn() -> Pipeline>)> = vec![
        (
            "select_project",
            Box::new(|| {
                Pipeline::new()
                    .select("v > -20 and k < 600")
                    .unwrap()
                    .project(&["k", "v"])
            }),
        ),
        (
            "select_project_probe_select",
            Box::new(move || {
                Pipeline::new()
                    .select("v > -60")
                    .unwrap()
                    .project(&["k", "v"])
                    .join("dim", inner())
                    .select("w < 8")
                    .unwrap()
            }),
        ),
        (
            "left_probe_groupby",
            Box::new(move || {
                Pipeline::new()
                    .select("k is not null")
                    .unwrap()
                    .join("dim", left())
                    .groupby(GroupByOptions::new(&["name"], aggs()))
            }),
        ),
        (
            "full_fused_pass",
            Box::new(move || {
                Pipeline::new()
                    .select("v > -60 and k < 550")
                    .unwrap()
                    .project(&["k", "v"])
                    .join("dim", inner())
                    .select("w < 9")
                    .unwrap()
                    .groupby(GroupByOptions::new(&["k"], aggs()))
            }),
        ),
        (
            "segments_split_by_orderby",
            Box::new(move || {
                Pipeline::new()
                    .select("v > -60")
                    .unwrap()
                    .join("dim", inner())
                    .orderby(vec![SortKey::asc("k"), SortKey::desc("name")])
                    .groupby(GroupByOptions::new(&["name"], aggs()))
            }),
        ),
    ];

    for (cname, chain) in &chains {
        // The `rows_out` oracle comes from the *unbatched* materialized
        // run: the batched streaming prefix times its stages but books
        // no row counts, while the fused executor (which ignores
        // batching — fusion already bounds intermediates) books every
        // stage at any batch_rows.
        let (_, oracle_phases) = exec::with_intra_op_threads(1, || {
            exec::with_pipeline_fuse(false, || {
                chain().run_local(&fact, &env).unwrap()
            })
        });
        for batch_rows in [0usize, 1024] {
            let pipe = chain().with_batch_rows(batch_rows);
            let run = || pipe.run_local(&fact, &env).unwrap();
            // Serial operator-at-a-time output is the oracle.
            let (mat, _) = exec::with_intra_op_threads(1, || {
                exec::with_pipeline_fuse(false, run)
            });
            for threads in [1usize, 2, 4, 8] {
                for steal in [true, false] {
                    let (fused, phases) =
                        exec::with_intra_op_threads(threads, || {
                            exec::with_work_steal(steal, || {
                                exec::with_pipeline_fuse(true, run)
                            })
                        });
                    assert_eq!(
                        fused, mat,
                        "{cname} fused diverged at {threads} threads, \
                         steal={steal}, batch_rows={batch_rows}"
                    );
                    assert_eq!(
                        phases.counter("rows_out"),
                        oracle_phases.counter("rows_out"),
                        "{cname} rows_out diverged at {threads} threads, \
                         steal={steal}, batch_rows={batch_rows}"
                    );
                }
            }
        }
    }
}

#[test]
fn spill_forcing_budget_matrix_bit_identical() {
    // The out-of-core acceptance gate (docs/MEMORY.md): join, sort,
    // and groupby under a memory budget chosen to force **zero**
    // (unbounded control), **one** (half the declared working set:
    // the whole input is denied, each hash partition is admitted),
    // and **recursive** (1 byte: every reservation is denied down to
    // the depth cap / run-size floor) spill levels must all produce
    // tables bit-identical to the unbounded in-memory oracle — at
    // 1/2/4/8 morsel workers, steal on and off. Every spill-forcing
    // run must also book partitions into the governor's counters,
    // and no run may leak a spill directory.
    fn check(label: &str, need: usize, run: &dyn Fn() -> Table) {
        let oracle = exec::with_intra_op_threads(1, || {
            exec::with_memory_budget_bytes(0, run)
        });
        for (budget, levels) in
            [(0usize, "zero"), (need / 2, "one"), (1, "recursive")]
        {
            for threads in [1usize, 2, 4, 8] {
                for steal in [true, false] {
                    let parts_before = exec::spill_partitions();
                    let dirs_before = exec::live_spill_dirs();
                    let out = exec::with_intra_op_threads(threads, || {
                        exec::with_work_steal(steal, || {
                            exec::with_memory_budget_bytes(budget, run)
                        })
                    });
                    assert_eq!(
                        out, oracle,
                        "{label} diverged at budget={budget} ({levels} \
                         spill levels), {threads} threads, steal={steal}"
                    );
                    let spilled =
                        exec::spill_partitions() - parts_before;
                    if budget == 0 {
                        assert_eq!(
                            spilled, 0,
                            "{label}: unbounded control must not spill"
                        );
                    } else {
                        assert!(
                            spilled > 0,
                            "{label}: budget={budget} ({levels}) must \
                             spill at least one partition"
                        );
                    }
                    assert_eq!(
                        exec::live_spill_dirs(),
                        dirs_before,
                        "{label}: leaked spill dir at budget={budget}"
                    );
                }
            }
        }
    }

    let l = random_table(61, 9_000, 300, 5);
    let r = random_table(62, 3_000, 250, 4);
    let jopts = JoinOptions::new(JoinType::FullOuter, &["k"], &["k"])
        .with_algo(JoinAlgo::Hash);
    let gopts = GroupByOptions::new(
        &["k"],
        vec![
            Agg::sum("v"),
            Agg::count("v"),
            Agg::mean("v"),
            Agg::max("s"),
        ],
    );
    let skeys = vec![SortKey::asc("k"), SortKey::desc("s")];

    // The working-set estimate each operator declares to the governor
    // (docs/MEMORY.md) — `need / 2` is therefore exactly the one-level
    // budget for that operator.
    check("join", l.byte_size() + r.byte_size(), &|| {
        join(&l, &r, &jopts).unwrap()
    });
    check("sort", l.byte_size() + 8 * l.num_rows(), &|| {
        orderby(&l, &skeys).unwrap()
    });
    check("groupby", l.byte_size(), &|| groupby(&l, &gopts).unwrap());
}

#[test]
fn pipeline_end_to_end_bit_identical() {
    // A realistic chain: filter → join → groupby → orderby, all under
    // one parallel budget vs serial.
    let fact = gen_table(&DataGenSpec::paper_scaling(20_000, 7)).unwrap();
    let dim = gen_table(&DataGenSpec {
        rows: 2_000,
        payload_cols: 1,
        key_dist: KeyDist::Sequential,
        seed: 8,
    })
    .unwrap();
    let run = || {
        let filtered =
            select(&fact, &Predicate::parse("d0 > 0").unwrap()).unwrap();
        let joined = join(
            &filtered,
            &dim,
            &JoinOptions::inner("id", "id").with_algo(JoinAlgo::Hash),
        )
        .unwrap();
        let grouped = groupby(
            &joined,
            &GroupByOptions::new(
                &["id"],
                vec![Agg::sum("d1"), Agg::count("d1")],
            ),
        )
        .unwrap();
        orderby(&grouped, &[SortKey::desc("sum_d1")]).unwrap()
    };
    let serial = exec::with_intra_op_threads(1, run);
    for &t in &THREADS {
        let par = exec::with_intra_op_threads(t, run);
        assert_eq!(par, serial, "pipeline diverged at {t} threads");
    }
}
