//! Scan-pushdown equivalence matrix (docs/STORAGE.md): encoded `RYF2`
//! scans must be bit-identical to the raw `RYF1` oracle across thread
//! counts and work-stealing modes while actually skipping groups via
//! zone maps; pushdown counters must total correctly across a
//! cluster's ranks; and encoded groups must round-trip through the
//! out-of-core operators' spill files.

use rylon::column::Column;
use rylon::dist::{Cluster, DistConfig};
use rylon::exec;
use rylon::io::ryf::{
    read_ryf, scan_ryf, write_ryf, RyfWriter, ScanOptions,
};
use rylon::ops::groupby::{groupby, Agg, GroupByOptions};
use rylon::ops::join::{join, JoinOptions};
use rylon::ops::orderby::{orderby, SortKey};
use rylon::ops::select::Predicate;
use rylon::pipeline::{Env, Pipeline};
use rylon::table::Table;

/// Sequential ids (ideal zone-map pruning), an f64 payload, a
/// low-cardinality string column (dictionary bait, prunable by
/// projection), and a nullable column whose nulls live only in the
/// last quarter of the rows — so pruning the null-carrying groups
/// exercises the validity-restore path.
fn dataset(n: usize) -> Table {
    let null_from = (n - n / 4) as i64;
    let tags: Vec<String> =
        (0..n).map(|i| format!("t{}", i % 7)).collect();
    Table::from_columns(vec![
        ("id", Column::from_i64((0..n as i64).collect())),
        (
            "v",
            Column::from_f64((0..n).map(|i| i as f64 * 0.5).collect()),
        ),
        (
            "w",
            Column::from_opt_i64(
                (0..n as i64)
                    .map(|i| {
                        if i < null_from {
                            Some(i * 2)
                        } else {
                            None
                        }
                    })
                    .collect(),
            ),
        ),
        (
            "tag",
            Column::from_str(
                &tags.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            ),
        ),
    ])
    .unwrap()
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rylon_ryfpd_{name}.ryf"))
}

#[test]
fn encoded_scan_matches_raw_oracle_across_threads_and_steal() {
    let table = dataset(4000);
    let enc = tmp("matrix_enc");
    let raw = tmp("matrix_raw");
    exec::with_ryf_encoding(true, || write_ryf(&table, &enc, 250))
        .unwrap();
    exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 250))
        .unwrap();
    let pipe = Pipeline::new()
        .select("id < 400")
        .unwrap()
        .project(&["id", "v", "w"]);
    let env = Env::new();
    let reference = exec::with_intra_op_threads(1, || {
        pipe.run_ryf_local(&raw, &env).unwrap().0
    });
    assert_eq!(reference.num_rows(), 400);
    let _ = exec::take_scan_stats();
    for threads in [1usize, 2, 4, 8] {
        for steal in [false, true] {
            exec::with_intra_op_threads(threads, || {
                exec::with_par_row_threshold(1, || {
                    exec::with_work_steal(steal, || {
                        let label =
                            format!("threads {threads} steal {steal}");
                        let (e, _) =
                            pipe.run_ryf_local(&enc, &env).unwrap();
                        let sc = exec::take_scan_stats();
                        assert_eq!(
                            e, reference,
                            "{label}: encoded diverged from the oracle"
                        );
                        assert_eq!(sc.groups_total, 16, "{label}");
                        assert_eq!(
                            sc.groups_skipped, 14,
                            "{label}: only groups 0 and 1 can hold \
                             id < 400"
                        );
                        assert!(
                            sc.decoded_bytes_avoided > 0,
                            "{label}"
                        );
                        assert_eq!(
                            sc.pruned_columns, 2,
                            "{label}: `tag` pruned in both survivors"
                        );
                        let (r, _) =
                            pipe.run_ryf_local(&raw, &env).unwrap();
                        let rc = exec::take_scan_stats();
                        assert_eq!(
                            r, reference,
                            "{label}: raw rerun diverged"
                        );
                        assert_eq!(
                            rc.groups_skipped, 0,
                            "{label}: raw files have no zone maps"
                        );
                    })
                })
            });
        }
    }
    std::fs::remove_file(&enc).ok();
    std::fs::remove_file(&raw).ok();
}

#[test]
fn dist_scan_counters_total_across_ranks() {
    let table = dataset(4000);
    let enc = tmp("dist_enc");
    let raw = tmp("dist_raw");
    exec::with_ryf_encoding(true, || write_ryf(&table, &enc, 250))
        .unwrap();
    exec::with_ryf_encoding(false, || write_ryf(&table, &raw, 250))
        .unwrap();
    let run = |path: &std::path::Path, encoding: bool| {
        let cluster = Cluster::new(
            DistConfig::threads(3).with_ryf_encoding(encoding),
        )
        .unwrap();
        let outs = cluster
            .run(|ctx| {
                let pipe = Pipeline::new()
                    .select("id < 400")?
                    .project(&["id", "v", "w"]);
                let (t, _) = pipe.run_ryf_dist(ctx, path, &Env::new())?;
                Ok(t)
            })
            .unwrap();
        (cluster.scan_stats(), outs)
    };
    let (sc, outs) = run(&enc, true);
    let (rc, routs) = run(&raw, false);
    assert_eq!(
        outs, routs,
        "per-rank encoded outputs must match the raw oracle"
    );
    let mut ids: Vec<i64> = outs
        .iter()
        .flat_map(|t| t.column(0).i64_values().to_vec())
        .collect();
    ids.sort();
    assert_eq!(ids, (0..400).collect::<Vec<_>>());
    // Every group is owned by exactly one rank, so the drained
    // per-rank counters total the whole file.
    assert_eq!(sc.groups_total, 16);
    assert_eq!(sc.groups_skipped, 14);
    assert!(sc.decoded_bytes > 0 && sc.decoded_bytes_avoided > 0);
    assert_eq!(sc.pruned_columns, 2);
    assert_eq!(rc.groups_total, 16);
    assert_eq!(rc.groups_skipped, 0, "raw files have no zone maps");
    std::fs::remove_file(&enc).ok();
    std::fs::remove_file(&raw).ok();
}

#[test]
fn encoded_groups_roundtrip_through_spill_dirs() {
    let table = dataset(2000);
    // SpillDir files are written by `RyfWriter` under the same
    // thread-local knob, so spilled groups are encoded when it is on —
    // and must read back exactly.
    let dirs_before = exec::live_spill_dirs();
    let dir = exec::SpillDir::create().unwrap();
    let spill = dir.file("part0.ryf");
    exec::with_ryf_encoding(true, || write_ryf(&table, &spill, 128))
        .unwrap();
    assert_eq!(&std::fs::read(&spill).unwrap()[..4], b"RYF2");
    assert_eq!(read_ryf(&spill).unwrap(), table);
    drop(dir);
    // Out-of-core join / sort / groupby under a one-byte budget (every
    // reservation denied → full spilling) must match the in-memory
    // results whichever format their spill files use.
    let keys = [SortKey::asc("tag"), SortKey::desc("id")];
    let gopts = GroupByOptions::new(
        &["tag"],
        vec![Agg::sum("v"), Agg::count("id")],
    );
    let jopts = JoinOptions::inner("id", "id");
    let (sorted0, grouped0, joined0) =
        exec::with_memory_budget_bytes(0, || {
            (
                orderby(&table, &keys).unwrap(),
                groupby(&table, &gopts).unwrap(),
                join(&table, &table, &jopts).unwrap(),
            )
        });
    for encoding in [false, true] {
        exec::with_ryf_encoding(encoding, || {
            exec::with_memory_budget_bytes(1, || {
                assert_eq!(
                    orderby(&table, &keys).unwrap(),
                    sorted0,
                    "out-of-core sort, encoding={encoding}"
                );
                assert_eq!(
                    groupby(&table, &gopts).unwrap(),
                    grouped0,
                    "out-of-core groupby, encoding={encoding}"
                );
                assert_eq!(
                    join(&table, &table, &jopts).unwrap(),
                    joined0,
                    "out-of-core join, encoding={encoding}"
                );
            })
        });
    }
    assert_eq!(
        exec::live_spill_dirs(),
        dirs_before,
        "a spill directory leaked"
    );
}

#[test]
fn streamed_encoded_appends_match_bulk_writes() {
    // The single-pass CSV→RYF convert appends streamed chunk tables
    // one group at a time; under the encoding knob that stream must
    // produce byte-identical files to the bulk writer, and pushdown
    // over them must behave identically.
    let table = dataset(1000);
    let streamed = tmp("stream_inc");
    let bulk = tmp("stream_bulk");
    exec::with_ryf_encoding(true, || -> rylon::Result<()> {
        let mut w = RyfWriter::create(&streamed)?;
        for g in 0..10 {
            w.append(&table.slice(g * 100, 100))?;
        }
        w.finish()?;
        write_ryf(&table, &bulk, 100)
    })
    .unwrap();
    assert_eq!(
        std::fs::read(&streamed).unwrap(),
        std::fs::read(&bulk).unwrap(),
        "streamed and bulk encoded writers must emit identical bytes"
    );
    let opts = ScanOptions {
        predicate: Some(Predicate::parse("id < 100").unwrap()),
        projection: Some(vec!["id".to_string(), "w".to_string()]),
    };
    let _ = exec::take_scan_stats();
    let got = scan_ryf(&streamed, &opts).unwrap();
    let c = exec::take_scan_stats();
    assert_eq!(c.groups_total, 10);
    assert_eq!(c.groups_skipped, 9);
    assert_eq!(c.pruned_columns, 2, "`v` and `tag` in the survivor");
    assert_eq!(got.num_rows(), 100);
    assert_eq!(got.num_columns(), 2);
    assert_eq!(got, scan_ryf(&bulk, &opts).unwrap());
    std::fs::remove_file(&streamed).ok();
    std::fs::remove_file(&bulk).ok();
}
