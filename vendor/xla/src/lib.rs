//! Offline stub of the xla-rs / PJRT binding.
//!
//! The build registry has no XLA runtime, so this crate mirrors the API
//! surface `rylon::runtime` consumes and fails — with a clear message —
//! at the one entry point that matters: [`PjRtClient::cpu`]. Because
//! `rylon::runtime::Runtime::open` constructs the client eagerly, every
//! AOT path degrades to the crate's bit-exact native fallbacks, which is
//! exactly the no-artifacts behaviour the test suite expects.
//!
//! Swap this path dependency for the real `xla` crate to run artifacts
//! through PJRT; no rylon source changes are needed.

use std::path::Path;

/// Error type mirroring xla-rs (callers format it with `{:?}`).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: XLA/PJRT runtime not available in this build (offline \
         stub crate; native fallbacks remain bit-exact)"
    )))
}

/// Host literal (stub: carries no data — unreachable without a client).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal)
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), XlaError> {
        unavailable("Literal::to_tuple3")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(
        _path: impl AsRef<Path>,
    ) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client. The stub fails at construction so callers fall back to
/// native kernels before any artifact is touched.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must not produce a client"),
        };
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literal_construction_is_safe() {
        let l = Literal::vec1(&[1i64, 2, 3]);
        assert!(l.reshape(&[3, 1]).is_ok());
        assert!(l.to_vec::<i64>().is_err());
    }
}
